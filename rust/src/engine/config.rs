//! One configuration surface for every engine.
//!
//! Before this module each engine grew its own `with_*` knob set —
//! `with_batch`/`with_workers` on [`super::ThreadedEngine`],
//! `with_window`/`with_peer`/`with_accept_timeout` on
//! [`super::ClusterEngine`], no-op parity stubs on [`super::LocalEngine`] —
//! and every new knob had to be copied into three builders (plus a CLI
//! parser). [`EngineConfig`] is the single owner of all of them: harness
//! code builds one config, hands it to whichever engine it chose via
//! `from_config`, and the engine reads the fields it understands while
//! ignoring the rest. The per-engine `with_*` methods survive as thin
//! forwarding wrappers, so existing call sites compile unchanged.
//!
//! [`EngineConfig::parse`] covers the spec-string path (`samoa exp
//! cluster` CLI, scripted sweeps): a comma-separated `key=value` list
//! such as `"workers=4,window=256,inject=32,peer=det,tcp"`.
//!
//! Knob ownership at a glance (✓ = read by that engine):
//!
//! | knob                | Local | Threaded | Cluster |
//! |---------------------|-------|----------|---------|
//! | `queue_capacity`    |       | ✓        |         |
//! | `batch_size`/`adaptive_batch` | | ✓    |         |
//! | `workers`           |       | ✓        | ✓       |
//! | `window`            |       |          | ✓       |
//! | `inject_window`     | ✓     |          | ✓       |
//! | `checkpoint_every`  |       | ✓        | ✓       |
//! | `replay_cap`        |       | ✓        | ✓       |
//! | `fault`             |       | ✓        |         |
//! | `restore_frames`    |       | ✓        |         |
//! | `peer`              |       |          | ✓       |
//! | `accept_secs`/`tcp` |       |          | ✓       |
//! | `measure_busy`      | ✓     |          | ✓       |
//! | `deep_copy_broadcast` | ✓   | ✓        |         |

use super::cluster::PeerMode;
use crate::Result;

/// Unified engine configuration. Defaults mirror [`super::ClusterEngine`]
/// where the engines historically disagreed (`replay_cap` 65536; the
/// threaded engine's own `Default` keeps its 4096) and the local/threaded
/// engines elsewhere. `workers: None` means "engine default": one thread
/// per instance on the threaded engine, 2 shards on the cluster engine.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Threaded: bound of each data channel, in batches.
    pub queue_capacity: usize,
    /// Threaded: micro-batch size (cap, when `adaptive_batch`).
    pub batch_size: usize,
    /// Threaded: adapt per-edge batch sizes.
    pub adaptive_batch: bool,
    /// Threaded: work-stealing worker count. Cluster: worker shards.
    /// `None` = engine default (pinned threads / 2 shards).
    pub workers: Option<usize>,
    /// Cluster: max un-acknowledged data deliveries per worker.
    pub window: usize,
    /// Local + cluster: source events injected per quiescence barrier.
    /// 1 (default) reproduces the classic inject-drain-inject loop; the
    /// cluster engine additionally coalesces each batch's same-worker
    /// runs into `FRAME_INJECT` wire frames (pipelined injection).
    pub inject_window: usize,
    /// Checkpoint every N events (0 = recovery off).
    pub checkpoint_every: u64,
    /// Bound of each replay log, in deliveries.
    pub replay_cap: usize,
    /// Threaded: fault injection `(pid, iid, kill after N events)`.
    pub fault: Option<(usize, usize, u64)>,
    /// Threaded: checkpoint frames applied at startup (rescale seeding).
    pub restore_frames: Vec<(usize, usize, Vec<u8>)>,
    /// Cluster: worker↔worker data plane mode.
    pub peer: PeerMode,
    /// Cluster subprocess mode: handshake deadline in seconds.
    pub accept_secs: u64,
    /// Cluster subprocess mode: TCP loopback instead of Unix sockets.
    pub tcp: bool,
    /// Instrument `process()` calls with wall-clock timing.
    pub measure_busy: bool,
    /// Bench baseline only: deep-copy broadcast deliveries.
    pub deep_copy_broadcast: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            queue_capacity: 1024,
            batch_size: 32,
            adaptive_batch: true,
            workers: None,
            window: 128,
            inject_window: 1,
            checkpoint_every: 0,
            replay_cap: 65536,
            fault: None,
            restore_frames: Vec::new(),
            peer: PeerMode::Off,
            accept_secs: 30,
            tcp: false,
            measure_busy: false,
            deep_copy_broadcast: false,
        }
    }
}

impl EngineConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fixed data-plane micro-batch size (adaptation off; threaded).
    pub fn with_batch(mut self, batch_size: usize) -> Self {
        self.batch_size = batch_size.max(1);
        self.adaptive_batch = false;
        self
    }

    /// Adaptive micro-batching with the given cap (threaded).
    pub fn with_adaptive_batch(mut self, cap: usize) -> Self {
        self.batch_size = cap.max(1);
        self.adaptive_batch = true;
        self
    }

    /// Unbounded data channels (threaded bench baseline).
    pub fn unbounded(mut self) -> Self {
        self.queue_capacity = usize::MAX;
        self
    }

    /// Worker count: stealing workers (threaded) or shards (cluster).
    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = Some(n.max(1));
        self
    }

    /// Cluster socket in-flight window.
    pub fn with_window(mut self, n: usize) -> Self {
        self.window = n.max(1);
        self
    }

    /// Source-injection window: events injected per quiescence barrier
    /// (local + cluster; 1 = classic per-event injection).
    pub fn with_inject_window(mut self, n: usize) -> Self {
        self.inject_window = n.max(1);
        self
    }

    /// Checkpoint every `every` events (0 = recovery off).
    pub fn with_checkpoints(mut self, every: u64) -> Self {
        self.checkpoint_every = every;
        self
    }

    /// Bound of each replay log.
    pub fn with_replay_cap(mut self, cap: usize) -> Self {
        self.replay_cap = cap.max(1);
        self
    }

    /// Threaded fault injection: kill `(pid, iid)` after `after` events.
    pub fn with_fault(mut self, pid: usize, iid: usize, after: u64) -> Self {
        self.fault = Some((pid, iid, after.max(1)));
        self
    }

    /// Threaded rescale seeding: checkpoint frames applied at startup.
    pub fn with_restore(mut self, frames: Vec<(usize, usize, Vec<u8>)>) -> Self {
        self.restore_frames = frames;
        self
    }

    /// Cluster worker↔worker data plane mode.
    pub fn with_peer(mut self, mode: PeerMode) -> Self {
        self.peer = mode;
        self
    }

    /// Cluster subprocess handshake deadline.
    pub fn with_accept_timeout(mut self, secs: u64) -> Self {
        self.accept_secs = secs.max(1);
        self
    }

    /// Cluster subprocess mode over TCP loopback.
    pub fn over_tcp(mut self) -> Self {
        self.tcp = true;
        self
    }

    /// Instrument `process()` calls with wall-clock timing.
    pub fn with_measure_busy(mut self, on: bool) -> Self {
        self.measure_busy = on;
        self
    }

    /// Parse a comma-separated `key=value` spec, e.g.
    /// `"workers=4,window=256,inject=32,peer=det,tcp"`. Bare `tcp`,
    /// `busy` and `peer` tokens act as flags (`peer` alone = `peer=det`);
    /// an empty string yields the default config. Unknown keys fail
    /// loudly so a typo cannot silently fall back to a default.
    pub fn parse(spec: &str) -> Result<EngineConfig> {
        let mut cfg = EngineConfig::default();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (k, v) = match tok.split_once('=') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (tok, None),
            };
            let uint = |v: Option<&str>| -> Result<u64> {
                v.ok_or_else(|| crate::anyhow!("engine config: '{k}' needs a value"))?
                    .parse::<u64>()
                    .map_err(|_| crate::anyhow!("engine config: bad number in '{tok}'"))
            };
            match k {
                "workers" => cfg.workers = Some((uint(v)? as usize).max(1)),
                "window" => cfg.window = (uint(v)? as usize).max(1),
                "inject" | "inject_window" => cfg.inject_window = (uint(v)? as usize).max(1),
                "batch" => {
                    cfg.batch_size = (uint(v)? as usize).max(1);
                    cfg.adaptive_batch = false;
                }
                "adaptive" => {
                    cfg.batch_size = (uint(v)? as usize).max(1);
                    cfg.adaptive_batch = true;
                }
                "queue" => cfg.queue_capacity = (uint(v)? as usize).max(1),
                "ckpt" | "checkpoint" => cfg.checkpoint_every = uint(v)?,
                "replay" | "replay_cap" => cfg.replay_cap = (uint(v)? as usize).max(1),
                "accept" => cfg.accept_secs = uint(v)?.max(1),
                "peer" => cfg.peer = PeerMode::parse(Some(v.unwrap_or("det")))?,
                "tcp" => cfg.tcp = true,
                "busy" => cfg.measure_busy = true,
                other => crate::bail!("engine config: unknown key '{other}' in '{spec}'"),
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let cfg =
            EngineConfig::parse("workers=4,window=256,inject=32,peer=fast,tcp,ckpt=64,replay=128")
                .expect("parse");
        assert_eq!(cfg.workers, Some(4));
        assert_eq!(cfg.window, 256);
        assert_eq!(cfg.inject_window, 32);
        assert_eq!(cfg.peer, PeerMode::Fast);
        assert!(cfg.tcp);
        assert_eq!(cfg.checkpoint_every, 64);
        assert_eq!(cfg.replay_cap, 128);
    }

    #[test]
    fn parse_defaults_and_flags() {
        let cfg = EngineConfig::parse("").expect("empty spec");
        assert_eq!(cfg.inject_window, 1);
        assert_eq!(cfg.workers, None);
        assert_eq!(cfg.peer, PeerMode::Off);

        let cfg = EngineConfig::parse("peer,busy").expect("flags");
        assert_eq!(cfg.peer, PeerMode::Deterministic);
        assert!(cfg.measure_busy);
    }

    #[test]
    fn parse_rejects_typos() {
        assert!(EngineConfig::parse("injekt=4").is_err());
        assert!(EngineConfig::parse("workers").is_err());
        assert!(EngineConfig::parse("window=abc").is_err());
        assert!(EngineConfig::parse("peer=sideways").is_err());
    }

    #[test]
    fn builder_clamps() {
        let cfg = EngineConfig::new().with_inject_window(0).with_workers(0).with_window(0);
        assert_eq!(cfg.inject_window, 1);
        assert_eq!(cfg.workers, Some(1));
        assert_eq!(cfg.window, 1);
    }
}
