//! Simulated-time engine: scaling curves on a single-core testbed.
//!
//! The paper's speedup figures (8, 9, 12) were measured on a 24-core Storm
//! cluster / 9-node Samza cluster. This container has one core, so real
//! threads cannot exhibit parallel speedup. Instead — per the substitution
//! rule in DESIGN.md §3 — we *measure* the true per-event compute cost of
//! every processor instance and the true message volume of every stream by
//! running the topology in the (instrumented) local engine, then evaluate
//! an analytic pipeline schedule for p workers:
//!
//! ```text
//! stage_time(P)  = max over instances i of P:
//!                    busy_ns(i) + rx_msgs(i)·c_msg + rx_bytes(i)·c_byte
//! source_time    = Σ emitted msgs · (c_msg + bytes·c_byte)   (serialization)
//! makespan       ≈ max(stage times, source_time)             (pipelining)
//! throughput     = source_instances / makespan
//! ```
//!
//! The per-message (`c_msg`) and per-byte (`c_byte`) constants default to
//! values calibrated against the single-partition Samza throughput line the
//! paper itself uses as reference in Fig. 13 (~40k msg/s at 1 KB ⇒
//! c_msg ≈ 12 µs, c_byte ≈ 8 ns/B) and are configurable per experiment.
//!
//! Because instance-level busy time is tracked (not just logical-stage
//! totals), key-grouping load imbalance — the vertical-parallelism drawback
//! discussed in §6.1 — shows up naturally as a longer max-instance time.

use crate::topology::builder::Topology;
use crate::topology::Event;

use super::local::LocalEngine;
use super::metrics::EngineMetrics;

/// Cost constants of the simulated cluster network.
#[derive(Clone, Copy, Debug)]
pub struct SimCostModel {
    /// Fixed per-message receive cost (dequeue + deserialize), ns.
    pub c_msg_ns: f64,
    /// Per-byte cost, ns.
    pub c_byte_ns: f64,
    /// Send side (serialize + enqueue) as a fraction of the receive cost,
    /// charged to the emitting stage. This is what eventually makes a
    /// single model aggregator the bottleneck as p grows (the paper's
    /// plateau beyond p ≈ 4-8 in Figs 8-9).
    pub tx_frac: f64,
    /// Per-backpressure-stall cost, ns: the price of a producer hitting a
    /// full bounded queue (on a real DSPE, a credit-replenishment round
    /// trip / spout-pending pause; in-process, a thread park + wake). The
    /// local engine records no stalls, so this term is zero for simtime's
    /// own runs; re-pricing metrics measured on the bounded threaded
    /// engine (see `EngineMetrics::flow`) charges each recorded stall.
    pub c_stall_ns: f64,
}

impl Default for SimCostModel {
    fn default() -> Self {
        // Calibrated against the paper's Fig. 13 reference line
        // (single-partition Samza stream: ~4·10^4 1KB-msgs/s); the stall
        // price is two context switches on commodity hardware. The
        // per-frame/per-byte split within that line follows the `samoa
        // exp cluster` wire-cost fit (least-squares over the null-topology
        // payload sweep), which puts proportionally more of a 1KB
        // message's cost on the fixed per-frame term than the previous
        // 15000/10 split did: 12000 + 1024·8 ≈ 20.2µs, ×(1+tx_frac)
        // ≈ 25µs/msg — on the 4·10^4 msgs/s reference.
        SimCostModel { c_msg_ns: 12_000.0, c_byte_ns: 8.0, tx_frac: 0.25, c_stall_ns: 5_000.0 }
    }
}

/// Result of a simulated run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub metrics: EngineMetrics,
    /// ns each logical stage would take end-to-end with its configured
    /// parallelism (max over instances of busy + communication).
    pub stage_ns: Vec<f64>,
    /// ns the source/serialization stage takes.
    pub source_ns: f64,
    /// ns charged for bounded-queue backpressure stalls recorded in the
    /// metrics (`flow.backpressure_stalls × c_stall_ns`; zero for runs
    /// under the local engine, which has no bounded queues).
    pub backpressure_ns: f64,
    /// Pipeline makespan, ns (includes `backpressure_ns`).
    pub makespan_ns: f64,
}

impl SimResult {
    /// Simulated throughput in source instances / second.
    pub fn throughput(&self) -> f64 {
        if self.makespan_ns <= 0.0 {
            return 0.0;
        }
        self.metrics.source_instances as f64 / (self.makespan_ns * 1e-9)
    }

    /// Wire bytes carried by one stream (per logical delivery — the
    /// quantity the cost model charges). Convenience for cost studies
    /// like `samoa exp sync-cost`, which reads the `StatsDelta` /
    /// `StatsGlobal` streams to price a sync policy.
    pub fn stream_bytes(&self, stream: crate::topology::StreamId) -> u64 {
        self.metrics.streams.get(stream.0).map_or(0, |s| s.bytes)
    }

    /// ns this run spends on communication alone under the cost model
    /// (every delivery's per-message + per-byte charge, receive and
    /// send side) — the sync-overhead denominator of the cost study.
    pub fn comm_ns(&self, cost: &SimCostModel) -> f64 {
        let msgs: f64 = self.metrics.streams.iter().map(|s| s.events as f64).sum();
        let bytes: f64 = self.metrics.streams.iter().map(|s| s.bytes as f64).sum();
        (msgs * cost.c_msg_ns + bytes * cost.c_byte_ns) * (1.0 + cost.tx_frac)
    }
}

/// Runs a topology under the instrumented local engine and prices the
/// result with a [`SimCostModel`].
pub struct SimTimeEngine {
    pub cost: SimCostModel,
}

impl Default for SimTimeEngine {
    fn default() -> Self {
        SimTimeEngine { cost: SimCostModel::default() }
    }
}

impl SimTimeEngine {
    pub fn new(cost: SimCostModel) -> Self {
        SimTimeEngine { cost }
    }

    /// Execute and price. `on_drain` has local-engine semantics.
    pub fn run(
        &self,
        topology: &Topology,
        entry: crate::topology::StreamId,
        source: impl Iterator<Item = Event>,
        on_drain: impl FnMut(&mut [Vec<Box<dyn crate::topology::Processor>>]),
    ) -> SimResult {
        let engine = LocalEngine { measure_busy: true, ..LocalEngine::default() };
        let metrics = engine.run(topology, entry, source, on_drain);
        self.price(topology, metrics)
    }

    /// Price already-collected metrics (lets one measured run be re-priced
    /// under several cost models, e.g. the Fig. 13 message-size sweep).
    pub fn price(&self, topology: &Topology, metrics: EngineMetrics) -> SimResult {
        // Communication charged to the receiving stage, split over its
        // instances the same way the engine routed them: we approximate
        // per-instance receive volume as stream totals / parallelism for
        // shuffle/key streams and full totals for broadcasts.
        let n_proc = topology.processors.len();
        let mut rx_msgs = vec![0.0f64; n_proc];
        let mut rx_bytes = vec![0.0f64; n_proc];
        let mut tx_msgs = vec![0.0f64; n_proc];
        let mut tx_bytes = vec![0.0f64; n_proc];
        for (sid, s) in topology.streams.iter().enumerate() {
            let m = &metrics.streams[sid];
            rx_msgs[s.to.0] += m.events as f64;
            rx_bytes[s.to.0] += m.bytes as f64;
            if let Some(from) = s.from {
                tx_msgs[from.0] += m.events as f64;
                tx_bytes[from.0] += m.bytes as f64;
            }
        }

        let mut stage_ns = Vec::with_capacity(n_proc);
        for (pid, p) in topology.processors.iter().enumerate() {
            let par = p.parallelism as f64;
            // max instance compute time (captures key imbalance)
            let max_busy = metrics.max_busy_ns(pid) as f64;
            // communication: per-instance share of receive volume + the
            // send-side serialization cost of everything this stage emits
            let comm = (rx_msgs[pid] / par) * self.cost.c_msg_ns
                + (rx_bytes[pid] / par) * self.cost.c_byte_ns
                + (tx_msgs[pid] / par) * self.cost.c_msg_ns * self.cost.tx_frac
                + (tx_bytes[pid] / par) * self.cost.c_byte_ns * self.cost.tx_frac;
            stage_ns.push(max_busy + comm);
        }

        // Source serialization: every emitted message is serialized once.
        let total_msgs: f64 = metrics.streams.iter().map(|s| s.events as f64).sum();
        let total_bytes: f64 = metrics.streams.iter().map(|s| s.bytes as f64).sum();
        let source_ns = total_msgs * self.cost.c_msg_ns * 0.1 // send side is cheaper than full hop
            + total_bytes * self.cost.c_byte_ns * 0.1;

        // Bounded-queue stalls (recorded only when pricing metrics from a
        // bounded threaded run) serialize the pipeline: each one pauses
        // the producer, so they add to the makespan rather than being
        // hidden by it.
        let backpressure_ns =
            metrics.flow.backpressure_stalls as f64 * self.cost.c_stall_ns;

        let makespan_ns = stage_ns
            .iter()
            .copied()
            .chain(std::iter::once(source_ns))
            .fold(0.0f64, f64::max)
            + backpressure_ns;

        SimResult { metrics, stage_ns, source_ns, backpressure_ns, makespan_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::topology::{Ctx, Grouping, Processor, TopologyBuilder};

    /// Burns deterministic CPU per event.
    struct Burn(u64);
    impl Processor for Burn {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            let mut x = 0u64;
            for i in 0..self.0 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(x);
        }
    }

    fn topo(par: usize) -> (crate::topology::Topology, crate::topology::StreamId) {
        let mut b = TopologyBuilder::new("t");
        let w = b.add_processor("w", par, |_| Box::new(Burn(20_000)));
        let entry = b.stream("src", None, w, Grouping::Shuffle);
        (b.build(), entry)
    }

    fn source(n: u64) -> impl Iterator<Item = Event> {
        (0..n).map(|id| Event::Instance { id, inst: Instance::dense(vec![0.0; 8], Label::None) })
    }

    #[test]
    fn more_parallelism_higher_throughput() {
        let eng = SimTimeEngine::default();
        let (t1, e1) = topo(1);
        let (t4, e4) = topo(4);
        let r1 = eng.run(&t1, e1, source(2000), |_| {});
        let r4 = eng.run(&t4, e4, source(2000), |_| {});
        assert!(
            r4.throughput() > r1.throughput() * 1.5,
            "p=4 {} vs p=1 {}",
            r4.throughput(),
            r1.throughput()
        );
    }

    /// Backpressure stalls recorded in engine metrics are priced into
    /// the makespan; local-engine runs (no bounded queues) charge zero.
    #[test]
    fn stalls_are_priced_into_makespan() {
        let eng = SimTimeEngine::default();
        let (t, e) = topo(2);
        let r = eng.run(&t, e, source(300), |_| {});
        assert_eq!(r.backpressure_ns, 0.0, "local engine records no stalls");
        // re-price the same measured metrics as if a bounded threaded run
        // had recorded 1000 stalls
        let mut metrics = r.metrics.clone();
        metrics.flow.backpressure_stalls = 1000;
        let repriced = eng.price(&t, metrics);
        let want = 1000.0 * eng.cost.c_stall_ns;
        assert!((repriced.backpressure_ns - want).abs() < 1e-6);
        assert!(repriced.makespan_ns >= r.makespan_ns + want - 1e-6);
        assert!(repriced.throughput() < r.throughput());
    }

    #[test]
    fn makespan_at_least_source_time() {
        let eng = SimTimeEngine::default();
        let (t, e) = topo(2);
        let r = eng.run(&t, e, source(500), |_| {});
        assert!(r.makespan_ns >= r.source_ns);
        assert!(r.throughput() > 0.0);
    }

    /// The stats-sync loop is priced like any other traffic: running the
    /// same sync topology with a tighter emission interval must show
    /// more delta-stream bytes under the cost model.
    #[test]
    fn sync_traffic_is_priced_by_the_cost_model() {
        use crate::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
        use crate::core::Schema;
        use crate::evaluation::prequential::{EvalSink, EvaluatorProcessor};
        use crate::preprocess::processor::{
            build_prequential_topology_head, LearnerHead, SyncPolicy,
        };
        use crate::preprocess::{Pipeline, StandardScaler};
        use crate::streams::waveform::WaveformGenerator;
        use crate::streams::StreamSource;
        use std::sync::Arc;

        let run = |interval: u64| {
            let mut stream = WaveformGenerator::classification(13);
            let schema = stream.schema().clone();
            let sink = EvalSink::new(schema.n_classes(), 1.0, 10_000);
            let sink2 = Arc::clone(&sink);
            let (topo, handles) = build_prequential_topology_head(
                &schema,
                4,
                Some(SyncPolicy::Count(interval)),
                |_| Pipeline::new().then(StandardScaler::new()),
                LearnerHead::Classifier(Box::new(
                    |s: &Schema| -> Box<dyn crate::core::model::Classifier> {
                        Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
                    },
                )),
                move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
            );
            let source = (0..2048u64).map_while(|id| {
                stream.next_instance().map(|inst| Event::Instance { id, inst })
            });
            let r = SimTimeEngine::default().run(&topo, handles.entry, source, |_| {});
            (r.stream_bytes(handles.delta.unwrap()), r)
        };
        let (bytes_tight, r_tight) = run(32);
        let (bytes_loose, r_loose) = run(512);
        assert!(
            bytes_tight > bytes_loose,
            "interval 32 must ship more sync bytes than 512 ({bytes_tight} vs {bytes_loose})"
        );
        let cost = SimCostModel::default();
        assert!(r_tight.comm_ns(&cost) > r_loose.comm_ns(&cost));
        assert!(r_tight.makespan_ns > 0.0 && r_loose.makespan_ns > 0.0);
    }
}
