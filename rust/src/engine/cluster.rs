//! Cluster engine: multi-process execution of a topology over sockets —
//! the first engine whose bytes physically leave the process, closing
//! the gap between `Event::wire_bytes()` (an estimate the simtime cost
//! model prices) and what a real DSPE serializes per hop.
//!
//! # Architecture
//!
//! One **coordinator** (this process) and `workers` **worker** shards.
//! Processor instances are assigned by instance index
//! (`instance i → worker i % workers`, so every shard of a parallel
//! processor lands on a different worker — vertical parallelism across
//! processes). Each worker is connected by **two socket lanes**:
//!
//! * a **data lane** carrying data-event deliveries, subject to the
//!   bounded in-flight window (backpressure at the socket boundary), and
//! * a **control lane** carrying control events (per `Event::is_control`)
//!   plus the protocol's shutdown/collect/halt frames. Control frames are
//!   exempt from the data window — the priority-lane property that keeps
//!   feedback loops (VHT `compute`/`local-result`, StatsSync rounds) and
//!   staged shutdown live no matter how congested the data plane is,
//!   mirroring the threaded engine's unbounded control channels.
//!
//! Every frame sent to a worker carries a per-worker monotone sequence
//! number (`wseq`); the worker merges both lanes back into contiguous
//! `wseq` order before processing. Lane priority is therefore a
//! *liveness* property (control is never blocked behind the data
//! window), never a *reordering* — which is what makes the execution
//! deterministic.
//!
//! # Determinism (golden equivalence with the local engine)
//!
//! The coordinator performs **all routing itself** — groupings,
//! round-robin cursors, broadcast fan-out, delayed-stream release, and
//! per-delivery `wire_bytes` metrics run the exact code path of
//! [`super::LocalEngine`]. Workers only execute `process()` and send
//! their emissions back; the coordinator consumes replies **in global
//! send order** and routes the returned emissions in that order. The
//! resulting global delivery sequence is bit-identical to the local
//! engine's FIFO drain, so totals, per-edge sequences and learned models
//! match the local engine exactly at any worker count
//! (`tests/cluster_equivalence.rs` pins this for VHT, AMRules and
//! StatsSync). Pipelining happens *within* each source cascade — up to
//! `window` un-acknowledged data deliveries per worker — while source
//! boundaries are quiescence barriers, exactly as in local execution.
//!
//! Staged shutdown mirrors the local engine too: per processor in pid
//! order, per instance, the coordinator sends an `on_shutdown` frame on
//! the control lane, consumes the reply, routes its emissions and drains
//! to cross-process quiescence before moving on — so e.g. a pipeline
//! shard's final stats delta is observable by the stats aggregator's
//! own shutdown flush, and the delta/master counts of
//! `tests/shard_skew_rounds.rs` are reproduced exactly.
//!
//! # Peer data plane (`with_peer`)
//!
//! By default every data delivery round-trips through the coordinator —
//! full fidelity, but the coordinator is the bottleneck ROADMAP names.
//! With [`ClusterEngine::with_peer`] the coordinator distributes a
//! routing table (`FRAME_ROUTES`: groupings, delays, shard ownership)
//! at startup and every worker pair opens a direct data socket. An
//! emission whose stream a worker can route without global state — any
//! data event on a delay-0 stream grouped Key/Direct/All, or Shuffle at
//! parallelism 1 (the shuffle cursor is global) — ships worker→worker
//! as a `FRAME_PEER` frame with a per-(sender,dest) sequence number,
//! while the sender's reply carries a *descriptor* instead of the
//! payload. The coordinator consumes descriptors in global send order,
//! so it still runs the exact local-engine metrics and still owns the
//! global delivery order: in `PeerMode::Deterministic` it assigns each
//! peer delivery the destination's next `wseq` slot and announces
//! `slot → sender` in out-of-band `FRAME_PEER_SCHED` tokens (they carry
//! no slot themselves), and the receiver merges coordinator frames and
//! per-sender peer FIFOs in contiguous slot order — bit-identical to
//! the coordinator-routed order, hence to the local engine.
//! `PeerMode::Fast` skips the slots: receivers process peer frames
//! whenever their coordinator-frame stream stalls and reply by
//! (sender, lseq) identity, conserving per-stream totals but relaxing
//! the global order. Control events, delayed streams, source injection
//! and the Shutdown/Collect/Snapshot/Restore protocol always stay on
//! the coordinator lanes. A worker always flushes its peer links
//! before its reply lane, so a consumed descriptor implies the peer
//! frames are on the wire — even if the sender dies right after, the
//! receiver still drains them (worker death degrades the respawned
//! shard to coordinator routing; see `recover_worker`).
//!
//! # Deadlock freedom
//!
//! Workers always drain their sockets (a dedicated reader thread per
//! lane feeds an in-memory reorder buffer), so a coordinator write can
//! never block indefinitely. The coordinator only blocks reading the
//! reply of the *oldest* outstanding delivery, whose worker is
//! guaranteed to reach it (its inputs are all flushed and it processes
//! in `wseq` order). Un-replied data deliveries are bounded by `window`
//! per worker (stalls land in `FlowControlMetrics`); control frames are
//! unbounded, as in the threaded engine.
//!
//! # Two spawn modes
//!
//! * [`ClusterEngine::run`] — workers are OS threads connected by real
//!   `UnixStream::pair` sockets. Processor factories run on the calling
//!   thread (they are not `Send`), instances move into worker threads.
//!   The full wire protocol is exercised; only process isolation is
//!   mocked. Integration tests use this mode (test binaries cannot
//!   re-exec themselves).
//! * [`ClusterEngine::run_spec`] — workers are genuine OS processes:
//!   the coordinator re-execs the `samoa` binary with the hidden
//!   `--cluster-worker` flag and a topology *spec string* (factories
//!   cannot cross a process boundary, so workers rebuild the topology
//!   from the spec registry in [`spec`]), over Unix-domain or TCP
//!   loopback sockets. `samoa exp cluster` and the CI smoke leg use
//!   this mode.
//!
//! Final worker state (accuracy, sync-round counters, split counts …)
//! returns to the coordinator through [`Processor::report`] key/value
//! frames — the cross-process replacement for `as_any` downcasting.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::common::cli::Args;
use crate::topology::builder::Topology;
use crate::topology::codec::{self, Reader};
use crate::topology::processor::{Ctx, Processor};
use crate::topology::stream::{Grouping, Route};
use crate::topology::{Event, StreamId};
use crate::{Context as _, Result};

use super::checkpoint::{LogOrigin, ReplayLog};
use super::config::EngineConfig;
use super::metrics::{ClusterMetrics, EngineMetrics, PeerLinkMetrics};

// Frame kinds. Every frame is `[len: u32 LE][kind: u8][wseq: u64 LE]…`;
// coordinator → worker kinds first, worker → coordinator kinds after.
const K_DELIVER: u8 = 1;
const K_SHUTDOWN: u8 = 2;
const K_COLLECT: u8 = 3;
const K_HALT: u8 = 4;
const K_EMISSIONS: u8 = 5;
const K_REPORT: u8 = 6;
const K_DONE: u8 = 7;
// Recovery protocol (enabled by `with_checkpoints`): the coordinator
// periodically asks each worker to snapshot its cells (the worker sends
// one K_SNAP per snapshottable cell, then K_DONE), and after respawning
// a dead worker pushes the held frames back with K_RESTORE (no reply;
// processed in wseq order like everything else).
const K_SNAPSHOT: u8 = 8;
const K_SNAP: u8 = 9;
const K_RESTORE: u8 = 10;

/// One pending delivery, exactly as in the local engine.
type Delivery = (usize, usize, Event);

/// Destination worker of instance `iid` (any processor): shards spread
/// across workers so a parallel processor parallelizes across processes.
#[inline]
fn worker_of(iid: usize, n_workers: usize) -> usize {
    iid % n_workers
}

/// Routing mode of the worker↔worker data plane.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PeerMode {
    /// Every delivery round-trips through the coordinator.
    #[default]
    Off,
    /// Peer links on; the coordinator schedules each peer delivery's
    /// global-order slot, so results stay bit-identical to the local
    /// engine (and to peer-off cluster runs).
    Deterministic,
    /// Peer links on; receivers process peer deliveries whenever their
    /// coordinator-frame stream stalls. Conserves per-stream totals but
    /// relaxes the global order (learned models may differ).
    Fast,
}

impl PeerMode {
    /// Parse the `--peer` CLI knob: bare `--peer` (= "true") or
    /// `--peer det` → deterministic, `--peer fast` → fast, absent → off.
    pub fn parse(v: Option<&str>) -> Result<PeerMode> {
        Ok(match v {
            None | Some("off") => PeerMode::Off,
            Some("fast") => PeerMode::Fast,
            Some("true" | "det" | "deterministic" | "1" | "yes") => PeerMode::Deterministic,
            Some(other) => crate::bail!("bad --peer mode '{other}' (expected det|fast|off)"),
        })
    }
}

/// Wire code of a grouping in the `FRAME_ROUTES` table.
fn grouping_code(g: Grouping) -> u8 {
    match g {
        Grouping::Key => 0,
        Grouping::Shuffle => 1,
        Grouping::All => 2,
        Grouping::Direct => 3,
    }
}

fn grouping_from_code(c: u8) -> Result<Grouping> {
    Ok(match c {
        0 => Grouping::Key,
        1 => Grouping::Shuffle,
        2 => Grouping::All,
        3 => Grouping::Direct,
        other => crate::bail!("cluster: bad grouping code {other}"),
    })
}

// ------------------------------------------------------------ transport

/// A duplex byte stream: Unix-domain (default, lowest latency) or TCP
/// loopback (`--tcp`; the shape a multi-host deployment would use).
enum Sock {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Sock {
    fn try_clone(&self) -> std::io::Result<Sock> {
        Ok(match self {
            Sock::Unix(s) => Sock::Unix(s.try_clone()?),
            Sock::Tcp(s) => Sock::Tcp(s.try_clone()?),
        })
    }

    /// Close both directions (unblocks any peer read); errors ignored —
    /// used on teardown paths where the socket may already be gone.
    fn shutdown(&self) {
        let _ = match self {
            Sock::Unix(s) => s.shutdown(std::net::Shutdown::Both),
            Sock::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }
}

impl Read for Sock {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Sock::Unix(s) => s.read(buf),
            Sock::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Sock {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Sock::Unix(s) => s.write(buf),
            Sock::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Sock::Unix(s) => s.flush(),
            Sock::Tcp(s) => s.flush(),
        }
    }
}

/// Write one length-prefixed frame.
fn write_frame<W: Write>(w: &mut W, body: &[u8]) -> std::io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)
}

/// Read one length-prefixed frame into `buf` (resized to fit).
fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<()> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    crate::ensure!(len > 0 && len <= codec::MAX_FRAME_BYTES, "cluster: bad frame length {len}");
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf)?;
    Ok(())
}

// ------------------------------------------------------------ worker side

/// Frames received by a worker, keyed by `wseq`, plus the peer-plane
/// receive state: the reorder buffer that merges the control and data
/// lanes (and, in peer mode, the worker↔worker links) back into one
/// deterministic order.
#[derive(Default)]
struct Inbox {
    frames: BTreeMap<u64, Vec<u8>>,
    /// Deterministic peer mode: slot → sending worker, distributed by
    /// the coordinator in out-of-band `FRAME_PEER_SCHED` tokens.
    sched: BTreeMap<u64, u8>,
    /// Per-sender FIFO of raw peer frames (self-deliveries included);
    /// frame order on one link *is* delivery order.
    peer_q: Vec<VecDeque<Vec<u8>>>,
    /// Workers the coordinator announced dead (`FRAME_PEER_DOWN`): stop
    /// peer-routing to them, their deliveries fall back to the
    /// coordinator path.
    down: Vec<bool>,
    /// Peer links whose socket hit EOF (the sender exited or died).
    peer_eof: Vec<bool>,
    /// A coordinator lane hit EOF or a read error: the coordinator hung up.
    eof: bool,
}

type SharedInbox = Arc<(Mutex<Inbox>, Condvar)>;

/// Per-lane reader: drains the socket unconditionally (the worker-side
/// half of the deadlock-freedom argument) into the shared inbox.
/// Out-of-band peer-plane frames (their wseq field is 0 and they consume
/// no slot) are routed to their own structures.
fn reader_loop(sock: Sock, inbox: SharedInbox) {
    let mut r = BufReader::new(sock);
    let mut buf = Vec::new();
    loop {
        let ok = read_frame(&mut r, &mut buf).is_ok() && buf.len() >= 9;
        let (lock, cv) = &*inbox;
        let mut g = lock.lock().unwrap();
        if !ok {
            g.eof = true;
            cv.notify_all();
            return;
        }
        match buf[0] {
            codec::FRAME_PEER_SCHED => match codec::decode_peer_sched(&buf) {
                Ok(tokens) => g.sched.extend(tokens),
                Err(_) => {
                    // A corrupt schedule would stall the merge forever;
                    // treat it like a hangup so the worker exits loudly.
                    g.eof = true;
                    cv.notify_all();
                    return;
                }
            },
            codec::FRAME_PEER_DOWN if buf.len() >= 10 => {
                let w = buf[9] as usize;
                if g.down.len() <= w {
                    g.down.resize(w + 1, false);
                }
                g.down[w] = true;
            }
            _ => {
                let wseq = u64::from_le_bytes(buf[1..9].try_into().unwrap());
                g.frames.insert(wseq, std::mem::take(&mut buf));
            }
        }
        cv.notify_all();
    }
}

/// Reader of one incoming worker↔worker link: drains raw peer frames
/// into the per-sender FIFO.
fn peer_reader_loop(sock: Sock, sender: usize, inbox: SharedInbox) {
    let mut r = BufReader::new(sock);
    let mut buf = Vec::new();
    loop {
        let ok = read_frame(&mut r, &mut buf).is_ok() && !buf.is_empty();
        let (lock, cv) = &*inbox;
        let mut g = lock.lock().unwrap();
        if !ok {
            if let Some(flag) = g.peer_eof.get_mut(sender) {
                *flag = true;
            }
            cv.notify_all();
            return;
        }
        g.peer_q[sender].push_back(std::mem::take(&mut buf));
        cv.notify_all();
    }
}

/// How a worker's peer data plane comes up.
enum PeerInit {
    /// Peer mode off — also used for respawned replacement workers,
    /// which are always degraded to coordinator routing.
    Off,
    /// Thread mode: the coordinator pre-connected the full mesh; entry
    /// `j` is the duplex socket to worker `j` (`None` at our own index).
    Mesh(Vec<Option<Sock>>),
    /// Subprocess mode: we own a listener; on `FRAME_ROUTES` we dial
    /// every lower-indexed peer and accept every higher-indexed one.
    Listen(PeerListener),
}

enum PeerListener {
    Unix(UnixListener, std::path::PathBuf),
    Tcp(TcpListener),
}

/// Worker-side peer plane, live once `FRAME_ROUTES` is processed.
struct PeerPlane {
    /// 1 = deterministic (slot-scheduled), 2 = fast (opportunistic).
    mode: u8,
    /// Recovery runs ship the event payload inside reply descriptors so
    /// the coordinator's replay log stays complete.
    recovery: bool,
    n_workers: usize,
    /// Our worker index (self-deliveries skip the socket).
    index: usize,
    /// Per stream: destination pid, grouping, delay — the routing table.
    streams: Vec<(usize, Grouping, u32)>,
    /// Per-stream round-robin cursors, seeded from FRAME_ROUTES. Only
    /// advanced for peer-eligible shuffle streams; the coordinator
    /// mirrors every advance from the reply descriptors.
    rr: Vec<usize>,
    /// Per-stream peer-route eligibility for shuffle at parallelism > 1
    /// (set by the coordinator only for sole-emitter streams, where the
    /// cursor mirror stays deterministic).
    shuffle_ok: Vec<bool>,
    /// Outgoing writer per destination worker (`None` at our own index).
    writers: Vec<Option<BufWriter<Sock>>>,
    /// Writers with unflushed frames since the last peer flush.
    writer_dirty: Vec<bool>,
    /// Writers that failed mid-run. Recovery mode tolerates this (the
    /// coordinator reroutes the affected deliveries); otherwise fatal.
    writer_dead: Vec<bool>,
    /// Next sequence number per (us → dest) link.
    lseq_out: Vec<u64>,
    /// Expected next sequence number per (sender → us) link.
    lseq_in: Vec<u64>,
}

/// Flush every dirtied peer writer. MUST run before any flush of the
/// reply lane: once the coordinator consumes a reply descriptor, the
/// matching peer frames have to be on the wire already — that is both
/// the liveness argument (the receiver's scheduled slot is satisfiable)
/// and what keeps the frames deliverable if this worker dies right
/// after replying.
fn flush_peer_writers(plane: &mut Option<PeerPlane>) -> Result<()> {
    let Some(p) = plane else { return Ok(()) };
    for d in 0..p.writers.len() {
        if !p.writer_dirty[d] || p.writer_dead[d] {
            continue;
        }
        p.writer_dirty[d] = false;
        if let Some(w) = p.writers[d].as_mut() {
            if let Err(e) = w.flush() {
                if p.recovery {
                    p.writer_dead[d] = true;
                } else {
                    return Err(e.into());
                }
            }
        }
    }
    Ok(())
}

/// Encode one delivery's emissions into the reply body `b`. Peer mode
/// off: the legacy flat `[stream][key][event]` list. Peer mode on: a
/// tagged list — tag 0 a full emission for the coordinator to route,
/// tag 1 a descriptor for a delivery shipped worker→worker right here
/// (one descriptor per destination instance, in local-engine fan-out
/// order), tag 2 a pre-routed shuffle emission whose rr cursor already
/// advanced but whose peer link is down (the coordinator delivers it to
/// the chosen instance without re-routing).
fn encode_emissions(
    b: &mut Vec<u8>,
    emissions: &[(StreamId, u64, Event)],
    plane: &mut Option<PeerPlane>,
    shape: &[usize],
    down: &[bool],
    inbox: &SharedInbox,
) -> Result<()> {
    let Some(p) = plane.as_mut() else {
        codec::put_u32(b, emissions.len() as u32);
        for (s, k, e) in emissions {
            codec::put_u32(b, s.0 as u32);
            codec::put_u64(b, *k);
            codec::encode_event(e, b);
        }
        return Ok(());
    };
    let n_pos = b.len();
    codec::put_u32(b, 0); // item count, patched below
    let mut items = 0u32;
    for (s, k, e) in emissions {
        let (dest, grouping, delay) = p.streams[s.0];
        let par = shape[dest];
        // Peer-eligible: data event, immediate stream, and a grouping we
        // can route locally. The shuffle cursor is global state; at
        // parallelism > 1 it routes here only when the coordinator marked
        // the stream `shuffle_ok` (sole emitter ⇒ the coordinator can
        // mirror our cursor advances deterministically).
        let shuffle_peer = matches!(grouping, Grouping::Shuffle) && par > 1;
        let eligible = !e.is_control()
            && delay == 0
            && (!shuffle_peer || p.shuffle_ok[s.0]);
        let dests: Vec<usize> = if eligible {
            match grouping.route(*k, par, &mut p.rr[s.0]) {
                Route::One(i) => vec![i],
                Route::All => (0..par).collect(),
            }
        } else {
            Vec::new()
        };
        let routable = !dests.is_empty()
            && dests.iter().all(|&t| {
                let d = worker_of(t, p.n_workers);
                !down.get(d).copied().unwrap_or(false) && !p.writer_dead[d]
            });
        if !routable {
            if shuffle_peer && !dests.is_empty() {
                // The cursor already advanced picking dests[0]; a tag-0
                // fallback would make the coordinator advance it again.
                // Ship the chosen destination as a pre-routed emission.
                codec::put_u8(b, 2);
                codec::put_u32(b, s.0 as u32);
                codec::put_u16(b, dests[0] as u16);
                codec::encode_event(e, b);
            } else {
                codec::put_u8(b, 0);
                codec::put_u32(b, s.0 as u32);
                codec::put_u64(b, *k);
                codec::encode_event(e, b);
            }
            items += 1;
            continue;
        }
        let wire = e.wire_bytes() as u32;
        for t in dests {
            let d = worker_of(t, p.n_workers);
            let lseq = p.lseq_out[d];
            p.lseq_out[d] += 1;
            let frame = codec::encode_peer_frame(lseq, dest as u16, t as u16, e);
            let enc = frame.len() as u32;
            if d == p.index {
                // Self-link: straight into our own inbox, no socket.
                let (lock, cv) = &**inbox;
                let mut g = lock.lock().unwrap();
                g.peer_q[d].push_back(frame);
                cv.notify_all();
            } else {
                let w = p.writers[d].as_mut().expect("peer writer missing");
                match write_frame(w, &frame) {
                    Ok(()) => p.writer_dirty[d] = true,
                    Err(err) if p.recovery => {
                        // The destination died; the coordinator will
                        // reroute this delivery from the descriptor.
                        let _ = err;
                        p.writer_dead[d] = true;
                    }
                    Err(err) => return Err(err.into()),
                }
            }
            codec::put_u8(b, 1);
            codec::put_u32(b, s.0 as u32);
            codec::put_u16(b, t as u16);
            codec::put_u32(b, wire);
            codec::put_u32(b, enc);
            if p.recovery {
                codec::put_u8(b, 1);
                codec::encode_event(e, b);
            } else {
                codec::put_u8(b, 0);
            }
            items += 1;
        }
    }
    b[n_pos..n_pos + 4].copy_from_slice(&items.to_le_bytes());
    Ok(())
}

/// Subprocess peer mesh: dial every lower-indexed worker's listener
/// (sending our index as a 1-byte hello), accept one connection from
/// every higher-indexed worker. Listeners are bound before the
/// coordinator handshake, so dials always land in a live backlog — no
/// ordering constraint between workers.
fn connect_peer_mesh(
    listener: &PeerListener,
    index: usize,
    n_workers: usize,
    addrs: &[String],
) -> Result<Vec<Option<Sock>>> {
    crate::ensure!(addrs.len() == n_workers, "cluster worker: peer address table mismatch");
    let mut socks: Vec<Option<Sock>> = (0..n_workers).map(|_| None).collect();
    for (j, addr) in addrs.iter().enumerate().take(index) {
        let mut s = if let Some(path) = addr.strip_prefix("unix:") {
            Sock::Unix(UnixStream::connect(path).with_context(|| format!("peer dial {path}"))?)
        } else if let Some(a) = addr.strip_prefix("tcp:") {
            Sock::Tcp(TcpStream::connect(a).with_context(|| format!("peer dial {a}"))?)
        } else {
            crate::bail!("cluster worker: bad peer address {addr}")
        };
        s.write_all(&[index as u8])?;
        s.flush()?;
        socks[j] = Some(s);
    }
    let secs = std::env::var("SAMOA_CLUSTER_ACCEPT_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(30)
        .max(1);
    let deadline = Instant::now() + std::time::Duration::from_secs(secs);
    for _ in index + 1..n_workers {
        let mut s = loop {
            let got = match listener {
                PeerListener::Unix(l, _) => {
                    l.set_nonblocking(true)?;
                    l.accept().map(|(s, _)| Sock::Unix(s))
                }
                PeerListener::Tcp(l) => {
                    l.set_nonblocking(true)?;
                    l.accept().map(|(s, _)| Sock::Tcp(s))
                }
            };
            match got {
                Ok(s) => break s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        crate::bail!("cluster worker {index}: timed out accepting peer links");
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        };
        match &s {
            Sock::Unix(u) => u.set_nonblocking(false)?,
            Sock::Tcp(t) => t.set_nonblocking(false)?,
        }
        let mut hello = [0u8; 1];
        s.read_exact(&mut hello)?;
        let j = hello[0] as usize;
        crate::ensure!(
            j > index && j < n_workers && socks[j].is_none(),
            "cluster worker {index}: unexpected peer hello from {j}"
        );
        socks[j] = Some(s);
    }
    if let PeerListener::Unix(_, path) = listener {
        let _ = std::fs::remove_file(path);
    }
    Ok(socks)
}

/// One processor instance living on this worker.
struct Cell {
    pid: usize,
    iid: usize,
    node: Box<dyn Processor>,
    processed: u64,
    busy_ns: u64,
}

/// One consumable unit popped from the worker's inbox.
enum Fetched {
    /// A coordinator frame (slot `next`).
    Frame(Vec<u8>),
    /// A peer delivery. `slot` is the coordinator-assigned global slot
    /// in deterministic mode, `None` in fast mode.
    Peer { sender: usize, frame: Vec<u8>, slot: Option<u64> },
    /// Scheduled sender died without its frame arriving — protocol loss,
    /// the worker bails and lets the coordinator's recovery see a death.
    Dead(usize),
    /// Coordinator hung up (normal after halt, or its run aborted).
    Eof,
}

/// Pop the next consumable unit, or `None` if the worker must wait.
/// Coordinator frames always win their slot; in deterministic mode a
/// slot the schedule assigns to a peer is satisfied only by that
/// sender's next frame, in fast mode any queued peer frame fills an
/// idle moment.
fn inbox_ready(g: &mut Inbox, next: u64, plane: Option<&PeerPlane>) -> Option<Fetched> {
    if let Some(b) = g.frames.remove(&next) {
        return Some(Fetched::Frame(b));
    }
    let p = plane?;
    if p.mode == 1 {
        let s = *g.sched.get(&next)? as usize;
        if let Some(f) = g.peer_q[s].pop_front() {
            g.sched.remove(&next);
            return Some(Fetched::Peer { sender: s, frame: f, slot: Some(next) });
        }
        if g.peer_eof[s] {
            return Some(Fetched::Dead(s));
        }
        None
    } else {
        for s in 0..g.peer_q.len() {
            if let Some(f) = g.peer_q[s].pop_front() {
                return Some(Fetched::Peer { sender: s, frame: f, slot: None });
            }
        }
        None
    }
}

fn peer_dirty(plane: &Option<PeerPlane>) -> bool {
    plane.as_ref().is_some_and(|p| p.writer_dirty.iter().any(|&d| d))
}

/// Worker main loop, shared by thread-mode and subprocess-mode workers:
/// merge lanes into `wseq` order, execute deliveries, reply with
/// emissions, report state on collect, exit on halt. `index` is this
/// worker's shard index; `peer_init` is how (or whether) the peer data
/// plane comes up when `FRAME_ROUTES` arrives.
fn serve(
    ctrl: Sock,
    data: Sock,
    owned: Vec<(usize, usize, Box<dyn Processor>)>,
    shape: Vec<usize>,
    measure_busy: bool,
    index: usize,
    peer_init: PeerInit,
) -> Result<()> {
    let inbox: SharedInbox = Arc::new((Mutex::new(Inbox::default()), Condvar::new()));
    let reply_sock = data.try_clone().context("cluster worker: clone data lane")?;
    // Kept so teardown can close the lanes even though the reader threads
    // own the primary handles — on an abnormal exit this unblocks both
    // our readers and a coordinator still waiting for a reply.
    let ctrl_shut = ctrl.try_clone().context("cluster worker: clone ctrl lane")?;
    let data_shut = data.try_clone().context("cluster worker: clone data lane")?;
    let readers = [
        std::thread::spawn({
            let inbox = Arc::clone(&inbox);
            move || reader_loop(ctrl, inbox)
        }),
        std::thread::spawn({
            let inbox = Arc::clone(&inbox);
            move || reader_loop(data, inbox)
        }),
    ];
    let mut out = BufWriter::new(reply_sock);
    let mut peer_init = Some(peer_init);
    let mut peer_handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut peer_shut: Vec<Sock> = Vec::new();

    let mut cells: Vec<Cell> = owned
        .into_iter()
        .map(|(pid, iid, node)| Cell { pid, iid, node, processed: 0, busy_ns: 0 })
        .collect();
    let index_map: HashMap<(usize, usize), usize> =
        cells.iter().enumerate().map(|(n, c)| ((c.pid, c.iid), n)).collect();

    // A panicking processor must not strand the coordinator: without the
    // catch, the serve thread unwinds past the teardown below while the
    // reader threads keep the sockets open, and the coordinator blocks on
    // a reply that will never come. Catching converts the panic into an
    // orderly socket shutdown — which is exactly the death signal the
    // coordinator's recovery path (`ClusterEngine::with_checkpoints`)
    // detects and repairs.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| -> Result<()> {
        let mut plane: Option<PeerPlane> = None;
        let mut next: u64 = 0;
        let mut dirty = false;
        loop {
            // Fetch slot `next` (or, peer mode, whatever the schedule /
            // fast rule allows), flushing buffered replies and peer
            // writers before any blocking wait (never while holding the
            // inbox lock: a flush may block on a socket and must not
            // stall the readers).
            let fetched = loop {
                {
                    let mut g = inbox.0.lock().unwrap();
                    if let Some(f) = inbox_ready(&mut g, next, plane.as_ref()) {
                        break f;
                    }
                    if g.eof {
                        break Fetched::Eof;
                    }
                    if !dirty && !peer_dirty(&plane) {
                        // Nothing buffered: sleep until a reader posts.
                        drop(inbox.1.wait(g).unwrap());
                        continue;
                    }
                }
                flush_peer_writers(&mut plane)?;
                out.flush()?;
                dirty = false;
            };
            let frame = match fetched {
                Fetched::Frame(b) => b,
                Fetched::Eof => return Ok(()),
                Fetched::Dead(s) => {
                    crate::bail!(
                        "cluster worker {index}: peer {s} died with scheduled frames missing"
                    )
                }
                Fetched::Peer { sender, frame, slot } => {
                    let (lseq, pid, iid, event) = codec::decode_peer_frame(&frame)?;
                    {
                        let p = plane.as_mut().expect("peer frame without peer plane");
                        crate::ensure!(
                            lseq == p.lseq_in[sender],
                            "cluster worker {index}: peer link {sender} out of order \
                             (got {lseq}, want {})",
                            p.lseq_in[sender]
                        );
                        p.lseq_in[sender] += 1;
                    }
                    let (pid, iid) = (pid as usize, iid as usize);
                    let Some(&n) = index_map.get(&(pid, iid)) else {
                        crate::bail!(
                            "cluster worker {index}: peer delivery for foreign instance \
                             ({pid},{iid})"
                        );
                    };
                    let cell = &mut cells[n];
                    let mut ctx = Ctx::new(iid, shape[pid]);
                    if measure_busy {
                        let t0 = Instant::now();
                        cell.node.process(event, &mut ctx);
                        cell.busy_ns += t0.elapsed().as_nanos() as u64;
                    } else {
                        cell.node.process(event, &mut ctx);
                    }
                    cell.processed += 1;
                    let emissions = ctx.take();
                    let down = inbox.0.lock().unwrap().down.clone();
                    let mut b = Vec::with_capacity(24 + 24 * emissions.len());
                    match slot {
                        Some(slot) => {
                            // Deterministic: the delivery owns global slot
                            // `slot`; reply exactly like a coordinator
                            // delivery so the merge stays positional.
                            codec::put_u8(&mut b, K_EMISSIONS);
                            codec::put_u64(&mut b, slot);
                            next += 1;
                        }
                        None => {
                            // Fast: out-of-slot reply keyed (sender, lseq).
                            codec::put_u8(&mut b, codec::FRAME_PEER_EMS);
                            codec::put_u64(&mut b, lseq);
                            codec::put_u8(&mut b, sender as u8);
                        }
                    }
                    encode_emissions(&mut b, &emissions, &mut plane, &shape, &down, &inbox)?;
                    flush_peer_writers(&mut plane)?;
                    write_frame(&mut out, &b)?;
                    dirty = true;
                    continue;
                }
            };
            next += 1;

            let mut r = Reader::new(&frame);
            let kind = r.u8()?;
            let wseq = r.u64()?;
            match kind {
                codec::FRAME_ROUTES => {
                    let mode = r.u8()?;
                    let recovery = r.u8()? != 0;
                    let n_workers = r.u16()? as usize;
                    let n_streams = r.u32()? as usize;
                    let mut streams = Vec::with_capacity(n_streams);
                    let mut rr_seeds = Vec::with_capacity(n_streams);
                    let mut shuffle_ok = Vec::with_capacity(n_streams);
                    for _ in 0..n_streams {
                        let dest = r.u16()? as usize;
                        let grouping = grouping_from_code(r.u8()?)?;
                        let delay = r.u32()?;
                        rr_seeds.push(r.u64()? as usize);
                        shuffle_ok.push(r.u8()? != 0);
                        streams.push((dest, grouping, delay));
                    }
                    let n_addr = r.u16()? as usize;
                    let mut addrs = Vec::with_capacity(n_addr);
                    for _ in 0..n_addr {
                        let l = r.u16()? as usize;
                        addrs.push(
                            String::from_utf8(r.bytes(l)?.to_vec())
                                .map_err(|_| crate::anyhow!("cluster: bad peer address"))?,
                        );
                    }
                    let socks = match peer_init.take() {
                        Some(PeerInit::Mesh(m)) => m,
                        Some(PeerInit::Listen(l)) => {
                            connect_peer_mesh(&l, index, n_workers, &addrs)?
                        }
                        Some(PeerInit::Off) | None => {
                            crate::bail!(
                                "cluster worker {index}: FRAME_ROUTES without peer transport"
                            )
                        }
                    };
                    crate::ensure!(
                        socks.len() == n_workers,
                        "cluster worker {index}: peer mesh size mismatch"
                    );
                    {
                        let mut g = inbox.0.lock().unwrap();
                        g.peer_q = (0..n_workers).map(|_| VecDeque::new()).collect();
                        if g.down.len() < n_workers {
                            g.down.resize(n_workers, false);
                        }
                        g.peer_eof = vec![false; n_workers];
                    }
                    let mut writers = Vec::with_capacity(n_workers);
                    for (j, s) in socks.into_iter().enumerate() {
                        let Some(s) = s else {
                            writers.push(None);
                            continue;
                        };
                        let rd = s.try_clone().context("cluster worker: clone peer link")?;
                        peer_shut.push(s.try_clone().context("cluster worker: clone peer link")?);
                        peer_handles.push(std::thread::spawn({
                            let inbox = Arc::clone(&inbox);
                            move || peer_reader_loop(rd, j, inbox)
                        }));
                        writers.push(Some(BufWriter::new(s)));
                    }
                    plane = Some(PeerPlane {
                        mode,
                        recovery,
                        n_workers,
                        index,
                        streams,
                        rr: rr_seeds,
                        shuffle_ok,
                        writers,
                        writer_dirty: vec![false; n_workers],
                        writer_dead: vec![false; n_workers],
                        lseq_out: vec![0; n_workers],
                        lseq_in: vec![0; n_workers],
                    });
                    // Slot-consuming, no reply: `wseq` is its position.
                    let _ = wseq;
                }
                K_DELIVER | K_SHUTDOWN => {
                    let pid = r.u16()? as usize;
                    let iid = r.u16()? as usize;
                    let Some(&n) = index_map.get(&(pid, iid)) else {
                        crate::bail!("cluster worker: not my instance ({pid},{iid})");
                    };
                    let cell = &mut cells[n];
                    let mut ctx = Ctx::new(iid, shape[pid]);
                    if kind == K_DELIVER {
                        let event = r.event()?;
                        if measure_busy {
                            let t0 = Instant::now();
                            cell.node.process(event, &mut ctx);
                            cell.busy_ns += t0.elapsed().as_nanos() as u64;
                        } else {
                            cell.node.process(event, &mut ctx);
                        }
                        cell.processed += 1;
                    } else {
                        cell.node.on_shutdown(&mut ctx);
                    }
                    let emissions = ctx.take();
                    let down = if plane.is_some() {
                        inbox.0.lock().unwrap().down.clone()
                    } else {
                        Vec::new()
                    };
                    let mut b = Vec::with_capacity(16 + 24 * emissions.len());
                    codec::put_u8(&mut b, K_EMISSIONS);
                    codec::put_u64(&mut b, wseq);
                    encode_emissions(&mut b, &emissions, &mut plane, &shape, &down, &inbox)?;
                    flush_peer_writers(&mut plane)?;
                    write_frame(&mut out, &b)?;
                    dirty = true;
                }
                K_COLLECT => {
                    for cell in &cells {
                        let mut b = Vec::with_capacity(64);
                        codec::put_u8(&mut b, K_REPORT);
                        codec::put_u64(&mut b, wseq);
                        codec::put_u16(&mut b, cell.pid as u16);
                        codec::put_u16(&mut b, cell.iid as u16);
                        codec::put_u64(&mut b, cell.node.mem_bytes() as u64);
                        codec::put_u64(&mut b, cell.processed);
                        codec::put_u64(&mut b, cell.busy_ns);
                        let kv = cell.node.report();
                        codec::put_u16(&mut b, kv.len() as u16);
                        for (name, v) in kv {
                            codec::put_u16(&mut b, name.len() as u16);
                            b.extend_from_slice(name.as_bytes());
                            codec::put_f64(&mut b, v);
                        }
                        write_frame(&mut out, &b)?;
                    }
                    let mut b = Vec::with_capacity(9);
                    codec::put_u8(&mut b, K_DONE);
                    codec::put_u64(&mut b, wseq);
                    flush_peer_writers(&mut plane)?;
                    write_frame(&mut out, &b)?;
                    out.flush()?;
                    dirty = false;
                }
                K_SNAPSHOT => {
                    for cell in &cells {
                        let Some(frame) = cell.node.snapshot() else { continue };
                        let mut b = Vec::with_capacity(21 + frame.len());
                        codec::put_u8(&mut b, K_SNAP);
                        codec::put_u64(&mut b, wseq);
                        codec::put_u16(&mut b, cell.pid as u16);
                        codec::put_u16(&mut b, cell.iid as u16);
                        codec::put_u32(&mut b, frame.len() as u32);
                        b.extend_from_slice(&frame);
                        write_frame(&mut out, &b)?;
                    }
                    let mut b = Vec::with_capacity(9);
                    codec::put_u8(&mut b, K_DONE);
                    codec::put_u64(&mut b, wseq);
                    flush_peer_writers(&mut plane)?;
                    write_frame(&mut out, &b)?;
                    out.flush()?;
                    dirty = false;
                }
                K_RESTORE => {
                    let pid = r.u16()? as usize;
                    let iid = r.u16()? as usize;
                    let n = r.u32()? as usize;
                    let frame = r.bytes(n)?;
                    let Some(&c) = index_map.get(&(pid, iid)) else {
                        crate::bail!("cluster worker: restore for foreign instance ({pid},{iid})");
                    };
                    cells[c].node.restore(frame).with_context(|| {
                        format!("cluster worker: restore rejected for ({pid},{iid})")
                    })?;
                }
                K_HALT => {
                    flush_peer_writers(&mut plane)?;
                    out.flush()?;
                    return Ok(());
                }
                codec::FRAME_INJECT => {
                    // Pipelined injection: a batch of deliveries in one
                    // frame, answered with one FRAME_INJECT_EMS reply
                    // carrying one emission group per delivery, in batch
                    // order. The frame occupies a single wseq slot.
                    let (fseq, batch) = codec::decode_inject_frame(&frame)?;
                    debug_assert_eq!(fseq, wseq);
                    let mut b = Vec::with_capacity(16 + 24 * batch.len());
                    codec::put_u8(&mut b, codec::FRAME_INJECT_EMS);
                    codec::put_u64(&mut b, wseq);
                    codec::put_u32(&mut b, batch.len() as u32);
                    for (pid, iid, event) in batch {
                        let (pid, iid) = (pid as usize, iid as usize);
                        let Some(&n) = index_map.get(&(pid, iid)) else {
                            crate::bail!("cluster worker: not my instance ({pid},{iid})");
                        };
                        let cell = &mut cells[n];
                        let mut ctx = Ctx::new(iid, shape[pid]);
                        if measure_busy {
                            let t0 = Instant::now();
                            cell.node.process(event, &mut ctx);
                            cell.busy_ns += t0.elapsed().as_nanos() as u64;
                        } else {
                            cell.node.process(event, &mut ctx);
                        }
                        cell.processed += 1;
                        let emissions = ctx.take();
                        // Fresh `down` per delivery: a peer may die while
                        // the batch is mid-flight.
                        let down = if plane.is_some() {
                            inbox.0.lock().unwrap().down.clone()
                        } else {
                            Vec::new()
                        };
                        encode_emissions(&mut b, &emissions, &mut plane, &shape, &down, &inbox)?;
                    }
                    flush_peer_writers(&mut plane)?;
                    write_frame(&mut out, &b)?;
                    dirty = true;
                }
                k => crate::bail!("cluster worker: unknown frame kind {k}"),
            }
        }
    }))
    .unwrap_or_else(|p| {
        let msg = p
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| p.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "unknown panic".to_string());
        Err(crate::anyhow!("cluster worker: processor panicked: {msg}"))
    });
    // Teardown: close both lanes and every peer link (no-op for lanes
    // the far side already closed), then collect the readers — they all
    // exit on EOF.
    ctrl_shut.shutdown();
    data_shut.shutdown();
    for s in &peer_shut {
        s.shutdown();
    }
    for h in peer_handles {
        let _ = h.join();
    }
    for h in readers {
        let _ = h.join();
    }
    result
}

// -------------------------------------------------------- coordinator side

/// Coordinator-side connection to one worker.
struct Link {
    ctrl: BufWriter<Sock>,
    data: BufWriter<Sock>,
    reply: BufReader<Sock>,
    ctrl_dirty: bool,
    data_dirty: bool,
    /// Next sequence number to stamp on a frame to this worker.
    wseq: u64,
    /// Un-replied data-lane deliveries (the backpressure window).
    inflight: usize,
    /// Deterministic peer mode: slot tokens `(slot, sender)` assigned to
    /// peer deliveries bound for this worker, not yet shipped.
    /// Materialized into one out-of-band `FRAME_PEER_SCHED` control
    /// frame by `flush` — the frame consumes no slot itself, otherwise
    /// scheduling a slot would consume a slot and never terminate.
    sched_pending: Vec<(u64, u8)>,
    /// Fast peer mode: replies that arrived ahead of the pending entry
    /// the coordinator is currently blocked on, keyed by reply identity
    /// (`(0, wseq, 0)` for slot replies, `(1, sender, lseq)` for
    /// out-of-slot peer replies). Deterministic mode never stashes.
    stash: HashMap<(u8, u64, u64), Vec<u8>>,
}

impl Link {
    /// Both lanes write on distinct sockets; replies ride the data
    /// socket's reverse direction (the worker's only upstream writer).
    fn new(ctrl: Sock, data: Sock) -> Result<Self> {
        let reply = BufReader::new(data.try_clone().context("cluster: clone data lane")?);
        Ok(Link {
            ctrl: BufWriter::new(ctrl),
            data: BufWriter::new(data),
            reply,
            ctrl_dirty: false,
            data_dirty: false,
            wseq: 0,
            inflight: 0,
            sched_pending: Vec::new(),
            stash: HashMap::new(),
        })
    }

    fn send(&mut self, body: &[u8], ctrl: bool, cm: &mut ClusterMetrics) -> Result<()> {
        let t0 = Instant::now();
        if ctrl {
            write_frame(&mut self.ctrl, body)?;
            self.ctrl_dirty = true;
            cm.ctrl_frames += 1;
        } else {
            write_frame(&mut self.data, body)?;
            self.data_dirty = true;
            cm.data_frames += 1;
        }
        cm.tx_bytes += 4 + body.len() as u64;
        cm.tx_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    fn flush(&mut self, cm: &mut ClusterMetrics) -> Result<()> {
        if !self.sched_pending.is_empty() {
            let b = codec::encode_peer_sched(&self.sched_pending);
            self.sched_pending.clear();
            let t0 = Instant::now();
            write_frame(&mut self.ctrl, &b)?;
            self.ctrl_dirty = true;
            cm.ctrl_frames += 1;
            cm.sched_frames += 1;
            cm.tx_bytes += 4 + b.len() as u64;
            cm.tx_ns += t0.elapsed().as_nanos() as u64;
        }
        if self.ctrl_dirty || self.data_dirty {
            let t0 = Instant::now();
            if self.ctrl_dirty {
                self.ctrl.flush()?;
                self.ctrl_dirty = false;
            }
            if self.data_dirty {
                self.data.flush()?;
                self.data_dirty = false;
            }
            cm.tx_ns += t0.elapsed().as_nanos() as u64;
        }
        Ok(())
    }

    fn read_reply(&mut self, buf: &mut Vec<u8>, cm: &mut ClusterMetrics) -> Result<()> {
        let t0 = Instant::now();
        read_frame(&mut self.reply, buf)?;
        cm.rx_bytes += 4 + buf.len() as u64;
        cm.reply_frames += 1;
        cm.rx_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }
}

/// Identity of an emissions reply, the key the coordinator matches (and
/// fast peer mode stashes) replies by: `(0, wseq, 0)` for slot replies,
/// `(1, sender, lseq)` for out-of-slot peer replies.
fn reply_id(buf: &[u8]) -> Result<(u8, u64, u64)> {
    let mut r = Reader::new(buf);
    match r.u8()? {
        K_EMISSIONS | codec::FRAME_INJECT_EMS => Ok((0, r.u64()?, 0)),
        codec::FRAME_PEER_EMS => {
            let lseq = r.u64()?;
            let sender = r.u8()? as u64;
            Ok((1, sender, lseq))
        }
        k => crate::bail!("cluster: unexpected reply kind {k}"),
    }
}

/// One un-replied delivery (or injection batch), in global send order.
struct Pending {
    worker: usize,
    wseq: u64,
    data: bool,
    /// Deliveries this entry covers in window and replay-log units: 1
    /// everywhere except FRAME_INJECT batches, where it is the batch
    /// run length (the reply carries that many emission groups).
    count: usize,
    /// Peer delivery: the `(sender, receiver)` link whose in-flight
    /// budget this entry holds (released when the reply lands).
    link: Option<(usize, usize)>,
    /// Fast peer mode: the `(sender, lseq)` reply identity expected for
    /// this entry (deterministic replies are identified by `wseq`).
    peer_key: Option<(u8, u64)>,
    /// Absolute replay-log index of this delivery (recovery mode only):
    /// the matching log entry is marked `replied` when the reply lands.
    log_ref: Option<u64>,
    /// Replay of an already-replied delivery: parse the reply, do NOT
    /// route its emissions (they were routed before the worker died).
    discard: bool,
}

/// One logged delivery awaiting a checkpoint that covers it. The log
/// itself is the generic bounded [`ReplayLog`] from
/// [`crate::engine::checkpoint`]; each entry carries a [`LogOrigin`] —
/// coordinator-routed vs shipped over a worker↔worker link — and a
/// `replied` flag (reply consumed pre-death ⇒ a re-drive rebuilds
/// worker state without re-routing the emissions).
struct LogEntry {
    pid: usize,
    iid: usize,
    event: Event,
    ctrl: bool,
}

/// Final state of one processor instance, reported across the process
/// boundary at collection time.
#[derive(Clone, Debug)]
pub struct InstanceReport {
    pub pid: usize,
    pub iid: usize,
    /// `Processor::mem_bytes` at shutdown.
    pub mem_bytes: u64,
    /// `Processor::report` key/value pairs.
    pub kv: Vec<(String, f64)>,
}

/// Result of a cluster run: engine metrics (identical quantities to the
/// local engine, plus the socket-plane counters in `metrics.cluster`)
/// and per-instance state reports.
#[derive(Clone, Debug)]
pub struct ClusterRun {
    pub metrics: EngineMetrics,
    pub reports: Vec<InstanceReport>,
}

impl ClusterRun {
    /// Value of `name` reported by instance (`pid`, `iid`).
    pub fn kv(&self, pid: usize, iid: usize, name: &str) -> Option<f64> {
        self.reports
            .iter()
            .find(|r| r.pid == pid && r.iid == iid)
            .and_then(|r| r.kv.iter().find(|(k, _)| k == name).map(|(_, v)| *v))
    }

    /// Sum of `name` across all instances that report it.
    pub fn kv_sum(&self, name: &str) -> f64 {
        self.reports
            .iter()
            .flat_map(|r| r.kv.iter())
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v)
            .sum()
    }
}

/// A peer delivery the coordinator knows about (from a reply descriptor)
/// but does not carry: the event bytes travel worker→worker; the
/// coordinator only sequences the delivery into the receiver's global
/// order (deterministic mode) and releases its link budget.
struct PeerMarker {
    /// Sending worker.
    sender: usize,
    dest_pid: usize,
    dest_iid: usize,
    dest_worker: usize,
    /// Per-(sender→dest_worker) sequence number, mirrored coordinator-side
    /// from descriptor order (replies are consumed in global send order).
    lseq: u64,
    /// Recovery mode ships the payload in the descriptor so the replay
    /// log stays complete and a dead receiver's deliveries can be
    /// re-routed through the coordinator.
    event: Option<Event>,
}

/// One unit of the coordinator's pending-delivery queue: a full delivery
/// the coordinator routes itself, or a marker for one already shipped
/// over a worker↔worker link.
enum QItem {
    Normal(Delivery),
    Peer(PeerMarker),
}

/// Coordinator drive state, shared by both spawn modes.
struct Coordinator<'a> {
    topology: &'a Topology,
    links: Vec<Link>,
    outstanding: VecDeque<Pending>,
    rr: Vec<usize>,
    queue: VecDeque<QItem>,
    delayed: VecDeque<(u64, Delivery)>,
    metrics: EngineMetrics,
    window: usize,
    /// Source-injection window (`EngineConfig::inject_window`): the pump
    /// coalesces up to this many consecutive same-worker data deliveries
    /// into one FRAME_INJECT batch. 1 = classic per-event shipping.
    inject: usize,
    buf: Vec<u8>,
    /// Recovery mode (`with_checkpoints`): per-worker replay logs, the
    /// coordinator-held snapshot frames, and the death bookkeeping.
    recovery_on: bool,
    replay_cap: usize,
    logs: Vec<ReplayLog<LogEntry>>,
    store: super::checkpoint::CheckpointStore,
    /// Worker whose socket just failed (set at the IO error site so the
    /// recovery path knows *who* died, not only that someone did).
    dead: Option<usize>,
    /// One respawn per worker per run; a second death is fatal.
    respawned: Vec<bool>,
    /// Peer data plane mode (`ClusterEngine::with_peer`).
    peer: PeerMode,
    /// Workers degraded back to coordinator routing (respawned
    /// replacements never get peer links; their replies are untagged).
    peer_off: Vec<bool>,
    /// Un-replied peer deliveries per (sender, receiver) link — the
    /// per-link in-flight window (flat n×n, index `sender * n + recv`
    /// via `peer_inflight[sender][recv]` as nested Vecs).
    peer_inflight: Vec<Vec<usize>>,
    /// Mirror of each sender's per-link sequence counter.
    peer_lseq: Vec<Vec<u64>>,
    /// Per-link traffic counters, flat n×n (`sender * n + recv`);
    /// compacted into `EngineMetrics::cluster.peer_links` at run end.
    peer_stats: Vec<PeerLinkMetrics>,
}

impl Coordinator<'_> {
    /// Route one emission — byte-for-byte the local engine's routing
    /// (groupings, rr cursors, broadcast fan-out, delayed buffering,
    /// per-delivery `wire_bytes` metrics), which is what makes cluster
    /// totals bit-identical to local totals.
    fn route_emission(&mut self, stream: StreamId, key: u64, event: Event, now: u64) {
        let topo = self.topology;
        let def = &topo.streams[stream.0];
        let dest = def.to.0;
        let par = topo.processors[dest].parallelism;
        let sm = &mut self.metrics.streams[stream.0];
        let queue = &mut self.queue;
        let delayed = &mut self.delayed;
        let mut push = |d: Delivery, bytes: usize| {
            sm.events += 1;
            sm.bytes += bytes as u64;
            if def.delay == 0 || now == u64::MAX {
                queue.push_back(QItem::Normal(d));
            } else {
                delayed.push_back((now + def.delay as u64, d));
            }
        };
        match def.grouping.route(key, par, &mut self.rr[stream.0]) {
            Route::One(i) => {
                let bytes = event.wire_bytes();
                push((dest, i, event), bytes);
            }
            Route::All => {
                let bytes = event.wire_bytes();
                for i in 0..par - 1 {
                    push((dest, i, event.clone()), bytes);
                }
                push((dest, par - 1, event), bytes);
            }
        }
    }

    /// Consume the reply of the *oldest* outstanding delivery and route
    /// its emissions. Replies are consumed strictly in global send order,
    /// so emissions append to the queue exactly where the local engine
    /// would append them.
    fn consume_one(&mut self, now: u64) -> Result<()> {
        let pend = self.outstanding.pop_front().expect("consume_one with nothing outstanding");
        self.consume_pending(pend, now)
    }

    /// Consume the reply of one specific pending delivery. An IO failure
    /// marks the worker dead (`self.dead`) before surfacing the error, so
    /// the recovery path in `drive` knows which shard to respawn.
    fn consume_pending(&mut self, pend: Pending, now: u64) -> Result<()> {
        // Replies from a worker with a live peer plane use the tagged
        // emission format; respawned replacements (and peer-off runs)
        // use the legacy flat one.
        let tagged = self.peer != PeerMode::Off && !self.peer_off[pend.worker];
        let want: (u8, u64, u64) = match pend.peer_key {
            Some((s, lseq)) => (1, s as u64, lseq),
            None => (0, pend.wseq, 0),
        };
        let mut buf = std::mem::take(&mut self.buf);
        if let Some(b) = self.links[pend.worker].stash.remove(&want) {
            buf = b;
        } else {
            loop {
                // Everything this reply causally depends on was sent to
                // the same worker with a smaller wseq (including pending
                // peer-schedule tokens); make sure none of it is still
                // sitting in our write buffers.
                let io = self.links[pend.worker].flush(&mut self.metrics.cluster).and_then(
                    |()| self.links[pend.worker].read_reply(&mut buf, &mut self.metrics.cluster),
                );
                if let Err(e) = io {
                    self.dead = Some(pend.worker);
                    self.buf = buf;
                    return Err(e);
                }
                let got = reply_id(&buf)?;
                if got == want {
                    break;
                }
                // Fast peer mode: the worker interleaves out-of-slot peer
                // replies with slot replies; park whatever arrived ahead
                // of the one this pending entry is blocked on.
                self.links[pend.worker].stash.insert(got, std::mem::take(&mut buf));
            }
        }
        {
            let mut r = Reader::new(&buf);
            let kind = r.u8()?;
            if kind == K_EMISSIONS || kind == codec::FRAME_INJECT_EMS {
                let wseq = r.u64()?;
                crate::ensure!(
                    wseq == pend.wseq,
                    "cluster: reply out of order (got {wseq}, expected {})",
                    pend.wseq
                );
            } else {
                let _lseq = r.u64()?;
                let _sender = r.u8()?;
            }
            if kind == codec::FRAME_INJECT_EMS {
                // Batched reply: one emission group per delivery in the
                // FRAME_INJECT batch, in batch order.
                let groups = r.u32()? as usize;
                crate::ensure!(
                    groups == pend.count,
                    "cluster: inject reply covers {groups} deliveries, expected {}",
                    pend.count
                );
                for _ in 0..groups {
                    self.consume_emission_group(pend.worker, &mut r, tagged, pend.discard, now)?;
                }
            } else {
                self.consume_emission_group(pend.worker, &mut r, tagged, pend.discard, now)?;
            }
        }
        self.buf = buf;
        if let Some(abs) = pend.log_ref {
            // A batch's log entries are consecutive (logged in one go in
            // `ship_injected`); the reply acknowledges all of them.
            for k in 0..pend.count as u64 {
                self.logs[pend.worker].mark_replied(abs + k);
            }
        }
        if let Some((a, b)) = pend.link {
            if self.peer_inflight[a][b] > 0 {
                self.peer_inflight[a][b] -= 1;
            }
        }
        if pend.data {
            self.links[pend.worker].inflight -= pend.count;
        }
        Ok(())
    }

    /// Consume one emission group — the `[n][emission × n]` block that
    /// follows a reply header — routing each emission exactly where the
    /// local engine would.
    fn consume_emission_group(
        &mut self,
        worker: usize,
        r: &mut Reader<'_>,
        tagged: bool,
        discard: bool,
        now: u64,
    ) -> Result<()> {
        let n = r.u32()?;
        for _ in 0..n {
            if tagged {
                match r.u8()? {
                    1 => {
                        self.consume_descriptor(worker, r, discard)?;
                        continue;
                    }
                    2 => {
                        self.consume_prerouted(r, discard)?;
                        continue;
                    }
                    0 => {}
                    t => crate::bail!("cluster: unknown emission tag {t}"),
                }
            }
            let s = StreamId(r.u32()? as usize);
            let k = r.u64()?;
            let e = r.event()?;
            if !discard {
                self.route_emission(s, k, e, now);
            }
        }
        Ok(())
    }

    /// Consume one tag-2 pre-routed emission: a shuffle delivery the
    /// worker routed itself with its seeded rr cursor but could not ship
    /// peer-to-peer (degraded or dead destination link). The destination
    /// instance is already chosen, so the coordinator delivers directly —
    /// re-routing would advance the shared cursor a second time.
    fn consume_prerouted(&mut self, r: &mut Reader<'_>, discard: bool) -> Result<()> {
        let stream = r.u32()? as usize;
        let iid = r.u16()? as usize;
        let e = r.event()?;
        if discard {
            // Replay of an already-consumed reply: counted and enqueued
            // the first time around.
            return Ok(());
        }
        let dest_pid = self.topology.streams[stream].to.0;
        let sm = &mut self.metrics.streams[stream];
        sm.events += 1;
        sm.bytes += e.wire_bytes() as u64;
        // Mirror the worker's cursor advance so a later degradation of
        // the sender leaves the coordinator's fallback cursor in step.
        self.rr[stream] = self.rr[stream].wrapping_add(1);
        // Eligible streams have delay == 0, so this never buffers.
        self.queue.push_back(QItem::Normal((dest_pid, iid, e)));
        Ok(())
    }

    /// Consume one tag-1 reply descriptor: a delivery the sender already
    /// shipped over its worker↔worker link. Mirrors the local engine's
    /// per-delivery stream metrics, mirrors the link's sequence counter,
    /// accumulates link traffic, and enqueues a [`PeerMarker`] at
    /// exactly the queue position the full delivery would have taken.
    fn consume_descriptor(
        &mut self,
        sender: usize,
        r: &mut Reader<'_>,
        discard: bool,
    ) -> Result<()> {
        let stream = r.u32()? as usize;
        let iid = r.u16()? as usize;
        let wire = r.u32()? as u64;
        let enc = r.u32()? as u64;
        let event = if r.u8()? != 0 { Some(r.event()?) } else { None };
        if discard {
            // Replay of an already-consumed reply: the marker was
            // enqueued (and everything counted) the first time around.
            return Ok(());
        }
        let dest_pid = self.topology.streams[stream].to.0;
        let n = self.links.len();
        let dest_worker = worker_of(iid, n);
        let sm = &mut self.metrics.streams[stream];
        sm.events += 1;
        sm.bytes += wire;
        let st = &mut self.peer_stats[sender * n + dest_worker];
        st.frames += 1;
        st.bytes += 4 + enc;
        st.wire_bytes += wire;
        let lseq = self.peer_lseq[sender][dest_worker];
        self.peer_lseq[sender][dest_worker] += 1;
        // Shuffle descriptors: the worker advanced its seeded rr cursor
        // to pick this destination (Shuffle ⇒ Route::One ⇒ exactly one
        // descriptor per emission). Mirror the advance so the coordinator
        // cursor stays in step for degraded-sender fallback routing.
        if matches!(self.topology.streams[stream].grouping, Grouping::Shuffle) {
            self.rr[stream] = self.rr[stream].wrapping_add(1);
        }
        if self.peer_off[dest_worker] {
            // The destination died after the sender shipped this: the
            // peer frame is gone with the dead socket, but recovery mode
            // put the payload in the descriptor — reroute it ourselves.
            let Some(e) = event else {
                crate::bail!("cluster: peer delivery to dead worker {dest_worker} without payload");
            };
            self.queue.push_back(QItem::Normal((dest_pid, iid, e)));
            return Ok(());
        }
        self.queue.push_back(QItem::Peer(PeerMarker {
            sender,
            dest_pid,
            dest_iid: iid,
            dest_worker,
            lseq,
            event,
        }));
        Ok(())
    }

    /// Ship one delivery to its owning worker, blocking on the window
    /// first if it is a data event.
    fn ship(&mut self, (p, i, e): Delivery, now: u64) -> Result<()> {
        let w = worker_of(i, self.links.len());
        let ctrl = e.is_control();
        if !ctrl {
            // Bounded-buffer backpressure at the socket boundary: block
            // until the oldest outstanding deliveries are acknowledged.
            while self.links[w].inflight >= self.window {
                self.metrics.flow.backpressure_stalls += 1;
                let t0 = Instant::now();
                self.consume_one(now)?;
                self.metrics.flow.backpressure_stall_ns += t0.elapsed().as_nanos() as u64;
            }
        }
        let link = &mut self.links[w];
        let wseq = link.wseq;
        link.wseq += 1;
        let mut b = Vec::with_capacity(24 + e.wire_bytes());
        codec::put_u8(&mut b, K_DELIVER);
        codec::put_u64(&mut b, wseq);
        codec::put_u16(&mut b, p as u16);
        codec::put_u16(&mut b, i as u16);
        codec::encode_event(&e, &mut b);
        if let Err(err) = link.send(&b, ctrl, &mut self.metrics.cluster) {
            self.dead = Some(w);
            return Err(err);
        }
        if !ctrl {
            self.links[w].inflight += 1;
        }
        let log_ref = if self.recovery_on {
            let (abs, dropped) = self.logs[w].push(
                LogEntry { pid: p, iid: i, event: e, ctrl },
                LogOrigin::Coordinator,
                self.replay_cap,
            );
            if dropped {
                self.metrics.recovery.replay_dropped += 1;
            }
            Some(abs)
        } else {
            None
        };
        self.outstanding.push_back(Pending {
            worker: w,
            wseq,
            data: !ctrl,
            count: 1,
            link: None,
            peer_key: None,
            log_ref,
            discard: false,
        });
        Ok(())
    }

    /// Ship one data delivery plus any consecutive same-worker data
    /// deliveries at the head of the queue as one FRAME_INJECT batch
    /// (pipelined injection, `inject_window > 1`). The whole batch costs
    /// one wire frame and one reply round-trip; it occupies `count`
    /// window slots and `count` replay-log entries, so backpressure and
    /// recovery see exactly the same deliveries as per-event shipping.
    fn ship_injected(&mut self, first: Delivery, now: u64) -> Result<()> {
        let w = worker_of(first.1, self.links.len());
        // Block on the window as `ship` does, but keep the head delivery
        // re-queueable: recovery re-enters pump and must find it again.
        while self.links[w].inflight >= self.window {
            self.metrics.flow.backpressure_stalls += 1;
            let t0 = Instant::now();
            if let Err(e) = self.consume_one(now) {
                self.queue.push_front(QItem::Normal(first));
                return Err(e);
            }
            self.metrics.flow.backpressure_stall_ns += t0.elapsed().as_nanos() as u64;
        }
        // Gather the run: consecutive normal data deliveries for the
        // same worker, up to the inject window and the free window slots.
        let cap = self.inject.min(self.window - self.links[w].inflight).max(1);
        let n_links = self.links.len();
        let mut batch: Vec<(u16, u16, Event)> = vec![(first.0 as u16, first.1 as u16, first.2)];
        while batch.len() < cap {
            let same_run = matches!(
                self.queue.front(),
                Some(QItem::Normal((_, i, e))) if !e.is_control() && worker_of(*i, n_links) == w
            );
            if !same_run {
                break;
            }
            let Some(QItem::Normal((p, i, e))) = self.queue.pop_front() else { unreachable!() };
            batch.push((p as u16, i as u16, e));
        }
        if batch.len() == 1 {
            // Run length 1: the plain per-event frame is smaller and
            // keeps the legacy wire trace byte-identical.
            let (p, i, e) = batch.pop().unwrap();
            return self.ship((p as usize, i as usize, e), now);
        }
        let count = batch.len();
        let link = &mut self.links[w];
        let wseq = link.wseq;
        link.wseq += 1;
        let b = codec::encode_inject_frame(wseq, &batch);
        if let Err(err) = link.send(&b, false, &mut self.metrics.cluster) {
            self.dead = Some(w);
            return Err(err);
        }
        self.links[w].inflight += count;
        self.metrics.flow.inject_frames += 1;
        self.metrics.flow.inject_events += count as u64;
        let log_ref = if self.recovery_on {
            // Log each delivery individually (consecutive abs indices);
            // recovery re-drives survivors as ordinary per-event frames.
            let mut base: Option<u64> = None;
            for (p, i, e) in batch {
                let (abs, dropped) = self.logs[w].push(
                    LogEntry { pid: p as usize, iid: i as usize, event: e, ctrl: false },
                    LogOrigin::Coordinator,
                    self.replay_cap,
                );
                if dropped {
                    self.metrics.recovery.replay_dropped += 1;
                }
                base.get_or_insert(abs);
            }
            base
        } else {
            None
        };
        self.outstanding.push_back(Pending {
            worker: w,
            wseq,
            data: true,
            count,
            link: None,
            peer_key: None,
            log_ref,
            discard: false,
        });
        Ok(())
    }

    /// Sequence one peer-shipped delivery: block on the link's in-flight
    /// window (and the receiver's slot window), then — deterministic
    /// mode — assign the receiver's next global slot to the sender's
    /// link via an out-of-band schedule token, or — fast mode — just
    /// account for the expected out-of-slot reply. No event bytes move
    /// here: they are already on (or through) the worker↔worker socket.
    fn ship_marker(&mut self, m: PeerMarker, now: u64) -> Result<()> {
        let (a, b) = (m.sender, m.dest_worker);
        let n = self.links.len();
        loop {
            let link_full = self.peer_inflight[a][b] >= self.window;
            let worker_full = self.links[b].inflight >= self.window;
            if !link_full && !worker_full {
                break;
            }
            if link_full {
                self.metrics.flow.peer_link_stalls += 1;
                self.peer_stats[a * n + b].stalls += 1;
            } else {
                self.metrics.flow.backpressure_stalls += 1;
            }
            let t0 = Instant::now();
            if let Err(e) = self.consume_one(now) {
                // Don't lose the marker: recovery re-enters pump and must
                // find it at the head of the queue again.
                self.queue.push_front(QItem::Peer(m));
                return Err(e);
            }
            let ns = t0.elapsed().as_nanos() as u64;
            if link_full {
                self.metrics.flow.peer_link_stall_ns += ns;
            } else {
                self.metrics.flow.backpressure_stall_ns += ns;
            }
        }
        let log_ref = if self.recovery_on {
            let event = m
                .event
                .clone()
                .ok_or_else(|| crate::anyhow!("cluster: recovery peer marker without payload"))?;
            let (abs, dropped) = self.logs[b].push(
                LogEntry { pid: m.dest_pid, iid: m.dest_iid, event, ctrl: false },
                LogOrigin::Peer { sender: a },
                self.replay_cap,
            );
            if dropped {
                self.metrics.recovery.replay_dropped += 1;
            }
            Some(abs)
        } else {
            None
        };
        self.peer_inflight[a][b] += 1;
        self.links[b].inflight += 1;
        match self.peer {
            PeerMode::Deterministic => {
                let link = &mut self.links[b];
                let slot = link.wseq;
                link.wseq += 1;
                link.sched_pending.push((slot, a as u8));
                self.outstanding.push_back(Pending {
                    worker: b,
                    wseq: slot,
                    data: true,
                    count: 1,
                    link: Some((a, b)),
                    peer_key: None,
                    log_ref,
                    discard: false,
                });
            }
            PeerMode::Fast => {
                self.outstanding.push_back(Pending {
                    worker: b,
                    wseq: 0,
                    data: true,
                    count: 1,
                    link: Some((a, b)),
                    peer_key: Some((a as u8, m.lseq)),
                    log_ref,
                    discard: false,
                });
            }
            PeerMode::Off => unreachable!("peer marker with peer mode off"),
        }
        Ok(())
    }

    /// Drain queue and outstanding replies to full quiescence — the
    /// cross-process equivalent of the local engine's `drain`.
    fn pump(&mut self, now: u64) -> Result<()> {
        loop {
            while let Some(item) = self.queue.pop_front() {
                match item {
                    QItem::Normal(d) => {
                        if self.inject > 1 && !d.2.is_control() {
                            self.ship_injected(d, now)?;
                        } else {
                            self.ship(d, now)?;
                        }
                    }
                    QItem::Peer(m) => self.ship_marker(m, now)?,
                }
            }
            if self.outstanding.is_empty() {
                return Ok(());
            }
            self.consume_one(now)?;
        }
    }

    /// Release matured delayed deliveries (local-engine semantics).
    fn release_delayed(&mut self, now: u64) {
        while self.delayed.front().map_or(false, |(at, _)| *at <= now) {
            let d = self.delayed.pop_front().unwrap().1;
            self.queue.push_back(QItem::Normal(d));
        }
    }

    /// Release everything still delayed (shutdown flush).
    fn release_all_delayed(&mut self) {
        while let Some((_, d)) = self.delayed.pop_front() {
            self.queue.push_back(QItem::Normal(d));
        }
    }

    /// One checkpoint round: at full quiescence (nothing outstanding),
    /// ask every worker to snapshot its cells, hold the frames
    /// coordinator-side, and clear the covered replay logs. Runs
    /// synchronously — the protocol guarantees the worker has processed
    /// every prior delivery before it answers, so the frames are exact.
    fn checkpoint_round(&mut self) -> Result<()> {
        debug_assert!(self.outstanding.is_empty(), "checkpoint round outside quiescence");
        let mut buf = std::mem::take(&mut self.buf);
        for w in 0..self.links.len() {
            let link = &mut self.links[w];
            let wseq = link.wseq;
            link.wseq += 1;
            let mut b = Vec::with_capacity(9);
            codec::put_u8(&mut b, K_SNAPSHOT);
            codec::put_u64(&mut b, wseq);
            let io = link
                .send(&b, true, &mut self.metrics.cluster)
                .and_then(|()| link.flush(&mut self.metrics.cluster));
            if let Err(e) = io {
                self.dead = Some(w);
                self.buf = buf;
                return Err(e);
            }
            loop {
                if let Err(e) = self.links[w].read_reply(&mut buf, &mut self.metrics.cluster) {
                    self.dead = Some(w);
                    self.buf = buf;
                    return Err(e);
                }
                let mut r = Reader::new(&buf);
                match r.u8()? {
                    K_SNAP => {
                        let _wseq = r.u64()?;
                        let pid = r.u16()? as usize;
                        let iid = r.u16()? as usize;
                        let n = r.u32()? as usize;
                        let frame = r.bytes(n)?;
                        self.metrics.recovery.checkpoints += 1;
                        self.metrics.recovery.checkpoint_bytes += frame.len() as u64;
                        self.store.put(pid, iid, frame.to_vec());
                    }
                    K_DONE => break,
                    k => crate::bail!("cluster: unexpected snapshot reply kind {k}"),
                }
            }
            self.logs[w].clear_covered();
        }
        self.buf = buf;
        Ok(())
    }

    /// Repair a dead worker: drain the live workers' outstanding replies
    /// (in global order), bring up a replacement link via `respawn`, push
    /// the held checkpoint frames, and re-drive the replay log — replies
    /// of entries the dead worker had already answered are parsed but
    /// their emissions discarded (they were routed pre-death), unreplied
    /// entries behave as fresh deliveries. State after recovery is
    /// bit-identical to a never-killed run iff the log covered the whole
    /// delta (`recovery.replay_dropped` stayed 0 for this worker).
    fn recover_worker(
        &mut self,
        w: usize,
        respawn: &mut dyn FnMut(usize) -> Result<Link>,
        now: u64,
    ) -> Result<()> {
        self.metrics.recovery.kills += 1;
        let n = self.links.len();
        let peer_was_on = self.peer != PeerMode::Off && !self.peer_off[w];
        if peer_was_on {
            // Degrade w to coordinator routing BEFORE the drain below:
            // descriptors consumed during it that target w must be
            // rerouted from their payload, not turned into markers for
            // frames that died with w's socket.
            self.peer_off[w] = true;
        }
        let outstanding: Vec<Pending> = self.outstanding.drain(..).collect();
        for pend in outstanding {
            if pend.worker == w {
                continue; // no reply will ever come; the log entry stays unreplied
            }
            self.consume_pending(pend, now)?;
        }
        // w's dropped pendings never released their link budgets (live
        // senders' budgets were released by the drain above — reset only
        // the dead-receiver column, and only after the drain).
        for a in 0..n {
            self.peer_inflight[a][w] = 0;
        }
        if peer_was_on {
            // Markers already queued for w reference peer frames that are
            // gone; convert them in place — same global queue position, so
            // the rerouted deliveries keep the local-engine order.
            for item in self.queue.iter_mut() {
                let QItem::Peer(m) = item else { continue };
                if m.dest_worker != w {
                    continue;
                }
                let Some(e) = m.event.take() else {
                    crate::bail!("cluster: peer marker for dead worker {w} without payload");
                };
                *item = QItem::Normal((m.dest_pid, m.dest_iid, e));
            }
            // Tell the live senders to stop peer-routing to w (out of
            // band: consumes no slot, like the schedule tokens).
            let mut b = Vec::with_capacity(10);
            codec::put_u8(&mut b, codec::FRAME_PEER_DOWN);
            codec::put_u64(&mut b, 0);
            codec::put_u8(&mut b, w as u8);
            for x in 0..n {
                if x == w || self.peer_off[x] {
                    continue;
                }
                let io = self.links[x]
                    .send(&b, true, &mut self.metrics.cluster)
                    .and_then(|()| self.links[x].flush(&mut self.metrics.cluster));
                if let Err(e) = io {
                    self.dead = Some(x);
                    return Err(e);
                }
            }
        }
        self.links[w] = respawn(w)?;
        let n_workers = self.links.len();
        let mut to_restore: Vec<(usize, usize, Vec<u8>)> = Vec::new();
        for (p, def) in self.topology.processors.iter().enumerate() {
            for i in 0..def.parallelism {
                if worker_of(i, n_workers) == w {
                    if let Some(frame) = self.store.get(p, i) {
                        to_restore.push((p, i, frame.to_vec()));
                    }
                }
            }
        }
        for (p, i, frame) in to_restore {
            let link = &mut self.links[w];
            let wseq = link.wseq;
            link.wseq += 1;
            let mut b = Vec::with_capacity(21 + frame.len());
            codec::put_u8(&mut b, K_RESTORE);
            codec::put_u64(&mut b, wseq);
            codec::put_u16(&mut b, p as u16);
            codec::put_u16(&mut b, i as u16);
            codec::put_u32(&mut b, frame.len() as u32);
            b.extend_from_slice(&frame);
            link.send(&b, true, &mut self.metrics.cluster)?;
            self.metrics.recovery.restores += 1;
        }
        for entry in self.logs[w].drain_for_redrive() {
            let LogEntry { pid, iid, event, ctrl } = entry.item;
            let link = &mut self.links[w];
            let wseq = link.wseq;
            link.wseq += 1;
            let mut b = Vec::with_capacity(24 + event.wire_bytes());
            codec::put_u8(&mut b, K_DELIVER);
            codec::put_u64(&mut b, wseq);
            codec::put_u16(&mut b, pid as u16);
            codec::put_u16(&mut b, iid as u16);
            codec::encode_event(&event, &mut b);
            link.send(&b, ctrl, &mut self.metrics.cluster)?;
            self.metrics.recovery.replayed += 1;
            let pend = Pending {
                worker: w,
                wseq,
                data: false, // inflight was never bumped for this re-send
                count: 1,
                link: None,
                peer_key: None,
                log_ref: None,
                discard: entry.replied,
            };
            self.consume_pending(pend, now)?;
        }
        Ok(())
    }
}

// -------------------------------------------------------------- the engine

/// Multi-process (or multi-thread-over-sockets) execution engine. See
/// the module docs for the architecture and determinism contract.
///
/// All knobs live on the unified [`EngineConfig`]; the `with_*` methods
/// below are thin forwarding wrappers kept for call-site compatibility
/// (and `samoa exp` ergonomics). Build from a shared config with
/// [`ClusterEngine::from_config`].
pub struct ClusterEngine {
    cfg: EngineConfig,
}

impl Default for ClusterEngine {
    fn default() -> Self {
        ClusterEngine { cfg: EngineConfig::default() }
    }
}

impl ClusterEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from the unified [`EngineConfig`]. Reads `workers`,
    /// `window`, `inject_window`, `checkpoint_every`, `replay_cap`,
    /// `peer`, `accept_secs`, `tcp` and `measure_busy`; threaded-only
    /// knobs (channels, batching, fault injection) do not apply here.
    pub fn from_config(cfg: &EngineConfig) -> Self {
        ClusterEngine { cfg: cfg.clone() }
    }

    /// Worker shards (`EngineConfig::workers`; `None` = 2 shards).
    fn n_workers(&self) -> usize {
        self.cfg.workers.unwrap_or(2).max(1)
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.cfg.workers = Some(n.max(1));
        self
    }

    /// Max un-acknowledged data deliveries per worker before the
    /// coordinator blocks (bounded-buffer backpressure at the socket).
    pub fn with_window(mut self, n: usize) -> Self {
        self.cfg.window = n.max(1);
        self
    }

    /// Pipelined source injection: up to `n` source events are injected
    /// per quiescence barrier, and each batch's same-worker runs ship
    /// as single `FRAME_INJECT` frames instead of per-event round
    /// trips. 1 (the default) is the classic per-event pump.
    pub fn with_inject_window(mut self, n: usize) -> Self {
        self.cfg.inject_window = n.max(1);
        self
    }

    /// Subprocess mode only: TCP loopback instead of Unix sockets.
    pub fn over_tcp(mut self) -> Self {
        self.cfg.tcp = true;
        self
    }

    /// Measure per-event `process()` wall time worker-side (reported
    /// back in the collect phase).
    pub fn with_measure_busy(mut self, on: bool) -> Self {
        self.cfg.measure_busy = on;
        self
    }

    /// Enable recovery: snapshot every worker every `every` source
    /// events (at the quiescence barrier) and keep per-worker replay
    /// logs, so one worker death per worker is repaired in place
    /// instead of failing the run. 0 disables recovery.
    pub fn with_checkpoints(mut self, every: u64) -> Self {
        self.cfg.checkpoint_every = every;
        self
    }

    /// Bound of each per-worker replay log. Deliveries evicted before a
    /// covering checkpoint count in `recovery.replay_dropped` and void
    /// the bit-identical recovery guarantee for that worker.
    pub fn with_replay_cap(mut self, cap: usize) -> Self {
        self.cfg.replay_cap = cap.max(1);
        self
    }

    /// Subprocess mode: seconds to wait for worker handshakes (spawn and
    /// respawn) before failing the run (overridable via
    /// `SAMOA_CLUSTER_ACCEPT_SECS` for loaded CI runners).
    pub fn with_accept_timeout(mut self, secs: u64) -> Self {
        self.cfg.accept_secs = secs.max(1);
        self
    }

    /// Enable the worker↔worker data plane. [`PeerMode::Deterministic`]
    /// keeps results bit-identical to the local engine (the coordinator
    /// still sequences every delivery, but the event bytes travel
    /// peer-to-peer); [`PeerMode::Fast`] also relaxes the cross-link
    /// ordering at each receiver.
    pub fn with_peer(mut self, mode: PeerMode) -> Self {
        self.cfg.peer = mode;
        self
    }

    /// Thread-mode run: workers are OS threads behind real Unix-socket
    /// pairs. Instances are constructed here (factories are not `Send`)
    /// and move into their worker thread.
    pub fn run(
        &self,
        topology: &Topology,
        entry: StreamId,
        source: impl Iterator<Item = Event>,
    ) -> Result<ClusterRun> {
        let n_workers = self.n_workers();
        let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
        let mut per_worker: Vec<Vec<(usize, usize, Box<dyn Processor>)>> =
            (0..n_workers).map(|_| Vec::new()).collect();
        for (p, def) in topology.processors.iter().enumerate() {
            for i in 0..def.parallelism {
                per_worker[worker_of(i, n_workers)].push((p, i, (def.factory)(i)));
            }
        }
        // Peer mode, thread flavor: pre-connect the full worker↔worker
        // mesh with socket pairs; each worker receives its row (its own
        // slot stays `None` — self-links never touch a socket).
        let peer_on = self.cfg.peer != PeerMode::Off;
        let mut mesh: Vec<Vec<Option<Sock>>> = if peer_on {
            (0..n_workers).map(|_| (0..n_workers).map(|_| None).collect()).collect()
        } else {
            Vec::new()
        };
        if peer_on {
            for i in 0..n_workers {
                for j in i + 1..n_workers {
                    let (a, b) = UnixStream::pair().context("cluster: peer socketpair")?;
                    mesh[i][j] = Some(Sock::Unix(a));
                    mesh[j][i] = Some(Sock::Unix(b));
                }
            }
        }
        let mut links = Vec::with_capacity(n_workers);
        let mut handles: Vec<Option<std::thread::JoinHandle<Result<()>>>> =
            Vec::with_capacity(n_workers);
        for (wi, owned) in per_worker.into_iter().enumerate() {
            let (c0, c1) = UnixStream::pair().context("cluster: socketpair")?;
            let (d0, d1) = UnixStream::pair().context("cluster: socketpair")?;
            let shape2 = shape.clone();
            let measure = self.cfg.measure_busy;
            let pinit = if peer_on {
                PeerInit::Mesh(std::mem::take(&mut mesh[wi]))
            } else {
                PeerInit::Off
            };
            handles.push(Some(std::thread::spawn(move || {
                serve(Sock::Unix(c1), Sock::Unix(d1), owned, shape2, measure, wi, pinit)
            })));
            links.push(Link::new(Sock::Unix(c0), Sock::Unix(d0))?);
        }
        // Recovery-mode respawn: reap the dead thread (its error already
        // surfaced coordinator-side as the socket failure), rebuild the
        // shard from the factories, serve it on fresh socket pairs. The
        // replacement starts blank — and always peer-less: the coordinator
        // has already degraded this shard to coordinator routing.
        let measure = self.cfg.measure_busy;
        let mut respawn = |w: usize| -> Result<Link> {
            if let Some(h) = handles[w].take() {
                let _ = h.join();
            }
            let mut owned: Vec<(usize, usize, Box<dyn Processor>)> = Vec::new();
            for (p, def) in topology.processors.iter().enumerate() {
                for i in 0..def.parallelism {
                    if worker_of(i, n_workers) == w {
                        owned.push((p, i, (def.factory)(i)));
                    }
                }
            }
            let (c0, c1) = UnixStream::pair().context("cluster: socketpair")?;
            let (d0, d1) = UnixStream::pair().context("cluster: socketpair")?;
            let shape2 = shape.clone();
            handles[w] = Some(std::thread::spawn(move || {
                serve(Sock::Unix(c1), Sock::Unix(d1), owned, shape2, measure, w, PeerInit::Off)
            }));
            Link::new(Sock::Unix(c0), Sock::Unix(d0))
        };
        // drive() owns the links and drops them on return, EOF-ing the
        // worker reader threads if anything aborted early.
        let result = self.drive(topology, entry, source, links, Some(&mut respawn), &[]);
        for h in handles.into_iter().flatten() {
            match h.join() {
                Ok(r) => r?,
                Err(_) => crate::bail!("cluster: worker thread panicked"),
            }
        }
        let (metrics, reports) = result?;
        Ok(ClusterRun { metrics, reports })
    }

    /// Subprocess-mode run: spawn `workers` copies of the `samoa` binary
    /// (hidden `--cluster-worker` flag), each rebuilding the topology
    /// from `spec` (see [`spec`]) and serving its instance shard over
    /// Unix-domain (default) or TCP loopback sockets.
    pub fn run_spec(
        &self,
        spec_str: &str,
        source: impl Iterator<Item = Event>,
    ) -> Result<ClusterRun> {
        let (topology, entry) = spec::build(spec_str)?;
        let n_workers = self.n_workers();
        let exe = std::env::current_exe().context("cluster: locate samoa binary")?;

        enum Listener {
            Unix(UnixListener, std::path::PathBuf),
            Tcp(TcpListener),
        }
        let (listener, addr) = if self.cfg.tcp {
            let l = TcpListener::bind("127.0.0.1:0").context("cluster: bind tcp")?;
            let addr = format!("tcp:{}", l.local_addr()?);
            (Listener::Tcp(l), addr)
        } else {
            // pid + per-process counter keep paths unique across
            // concurrent coordinators and repeated runs in one process
            let salt = {
                use std::sync::atomic::{AtomicU64, Ordering};
                static SALT: AtomicU64 = AtomicU64::new(0);
                SALT.fetch_add(1, Ordering::Relaxed)
            };
            let path = std::env::temp_dir()
                .join(format!("samoa-cluster-{}-{salt}.sock", std::process::id()));
            let _ = std::fs::remove_file(&path);
            let l = UnixListener::bind(&path)
                .with_context(|| format!("cluster: bind {}", path.display()))?;
            (Listener::Unix(l, path.clone()), format!("unix:{}", path.display()))
        };

        // Worker stderr is piped so a startup or mid-run death can be
        // diagnosed from the coordinator's error message. Workers print
        // nothing in normal operation, so the pipe buffer never fills.
        let spawn_worker = |spec: &str, k: usize, peer: bool| -> Result<std::process::Child> {
            let mut cmd = std::process::Command::new(&exe);
            cmd.arg("--cluster-worker")
                .arg(&addr)
                .arg("--cluster-spec")
                .arg(spec)
                .arg("--cluster-index")
                .arg(k.to_string())
                .arg("--cluster-workers")
                .arg(n_workers.to_string());
            if self.cfg.measure_busy {
                cmd.arg("--cluster-measure");
            }
            if peer {
                cmd.arg("--cluster-peer");
            }
            cmd.stderr(std::process::Stdio::piped());
            cmd.spawn().context("cluster: spawn worker process")
        };
        let peer_on = self.cfg.peer != PeerMode::Off;
        let mut children = Vec::with_capacity(n_workers);
        for k in 0..n_workers {
            children.push(spawn_worker(spec_str, k, peer_on)?);
        }

        // Accept 2 connections per worker; each starts with a 2-byte
        // handshake [worker index, lane (0 = ctrl, 1 = data)]. Non-blocking
        // accept with a deadline so a worker that dies on startup fails the
        // run instead of hanging it.
        let accept = |deadline: Instant, children: &mut [std::process::Child]| -> Result<Sock> {
            loop {
                let got = match &listener {
                    Listener::Unix(l, _) => {
                        l.set_nonblocking(true)?;
                        l.accept().map(|(s, _)| Sock::Unix(s))
                    }
                    Listener::Tcp(l) => {
                        l.set_nonblocking(true)?;
                        l.accept().map(|(s, _)| Sock::Tcp(s))
                    }
                };
                match got {
                    Ok(s) => return Ok(s),
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        for (k, c) in children.iter_mut().enumerate() {
                            if let Ok(Some(status)) = c.try_wait() {
                                // The process has exited, so draining the
                                // pipe cannot block.
                                let mut err = String::new();
                                if let Some(mut pipe) = c.stderr.take() {
                                    let _ = pipe.read_to_string(&mut err);
                                }
                                let err = err.trim();
                                let sep = if err.is_empty() { "" } else { "; stderr: " };
                                crate::bail!(
                                    "cluster: worker {k} exited while the coordinator \
                                     waited for its handshake ({status}){sep}{err}"
                                );
                            }
                        }
                        if Instant::now() > deadline {
                            crate::bail!("cluster: timed out waiting for workers to connect");
                        }
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        };

        let mut ctrl: Vec<Option<Sock>> = (0..n_workers).map(|_| None).collect();
        let mut data: Vec<Option<Sock>> = (0..n_workers).map(|_| None).collect();
        // `SAMOA_CLUSTER_ACCEPT_SECS` overrides the builder value so a
        // loaded CI runner can stretch the handshake window without a
        // recompile.
        let accept_secs = std::env::var("SAMOA_CLUSTER_ACCEPT_SECS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(self.cfg.accept_secs)
            .max(1);
        let deadline = Instant::now() + std::time::Duration::from_secs(accept_secs);
        let setup = (|| -> Result<()> {
            for _ in 0..2 * n_workers {
                let mut s = accept(deadline, &mut children)?;
                let mut hs = [0u8; 2];
                // the sock inherited non-blocking from the listener on some
                // platforms; force blocking for the stream itself
                match &s {
                    Sock::Unix(u) => u.set_nonblocking(false)?,
                    Sock::Tcp(t) => t.set_nonblocking(false)?,
                }
                s.read_exact(&mut hs)?;
                let (idx, lane) = (hs[0] as usize, hs[1]);
                crate::ensure!(idx < n_workers, "cluster: handshake from unknown worker {idx}");
                let slot = if lane == 0 { &mut ctrl[idx] } else { &mut data[idx] };
                crate::ensure!(slot.is_none(), "cluster: duplicate lane {lane} from {idx}");
                *slot = Some(s);
            }
            Ok(())
        })();
        // Recovery mode keeps the listener address connectable so a
        // respawned worker can dial back in; otherwise the Unix path is
        // removed as soon as the initial handshakes are in.
        let recovery_on = self.cfg.checkpoint_every > 0;
        if !recovery_on {
            if let Listener::Unix(_, path) = &listener {
                let _ = std::fs::remove_file(path);
            }
        }

        // Recovery-mode respawn: reap the dead child, spawn a replacement
        // on the *fault-stripped* spec (an injected `die=` bomb must not
        // re-arm — the restored event count is below the threshold, so a
        // rearmed replacement would re-cross it during replay, forever),
        // and take its two handshakes off the shared listener.
        let stripped = spec::strip_fault(spec_str);
        let mut respawn = |w: usize| -> Result<Link> {
            let _ = children[w].wait();
            // Replacements are always peer-less (degraded to coordinator
            // routing), so they never see FRAME_ROUTES and reply in the
            // legacy untagged format.
            children[w] = spawn_worker(&stripped, w, false)?;
            let deadline = Instant::now() + std::time::Duration::from_secs(accept_secs);
            let mut rc: Option<Sock> = None;
            let mut rd: Option<Sock> = None;
            for _ in 0..2 {
                let mut s = accept(deadline, &mut children)?;
                match &s {
                    Sock::Unix(u) => u.set_nonblocking(false)?,
                    Sock::Tcp(t) => t.set_nonblocking(false)?,
                }
                let mut hs = [0u8; 2];
                s.read_exact(&mut hs)?;
                crate::ensure!(
                    hs[0] as usize == w,
                    "cluster: handshake from unexpected worker {} during respawn of {w}",
                    hs[0]
                );
                let slot = if hs[1] == 0 { &mut rc } else { &mut rd };
                crate::ensure!(slot.is_none(), "cluster: duplicate lane from respawned {w}");
                *slot = Some(s);
            }
            Link::new(rc.unwrap(), rd.unwrap())
        };

        let result = setup.and_then(|()| {
            // Peer mode: each worker bound its own peer listener before
            // handshaking and announced it with one FRAME_PEER_ADDR on
            // the control lane; collect the address table to broadcast
            // in FRAME_ROUTES. (The control lane's reverse direction is
            // otherwise unused, so reading here races nothing.)
            let mut peer_addrs: Vec<String> = Vec::with_capacity(n_workers);
            if peer_on {
                let mut fbuf = Vec::new();
                for (k, c) in ctrl.iter_mut().enumerate() {
                    let s = c.as_mut().expect("ctrl sock");
                    read_frame(s, &mut fbuf)
                        .with_context(|| format!("cluster: peer address from worker {k}"))?;
                    crate::ensure!(
                        fbuf.len() > 9 && fbuf[0] == codec::FRAME_PEER_ADDR,
                        "cluster: worker {k} sent no peer address"
                    );
                    peer_addrs.push(String::from_utf8_lossy(&fbuf[9..]).into_owned());
                }
            }
            let mut links = Vec::with_capacity(n_workers);
            for (c, d) in ctrl.into_iter().zip(data) {
                links.push(Link::new(c.unwrap(), d.unwrap())?);
            }
            self.drive(&topology, entry, source, links, Some(&mut respawn), &peer_addrs)
        });
        if let Listener::Unix(_, path) = &listener {
            let _ = std::fs::remove_file(path);
        }
        for mut c in children {
            if result.is_err() {
                let _ = c.kill();
            }
            let _ = c.wait();
        }
        let (metrics, reports) = result?;
        Ok(ClusterRun { metrics, reports })
    }

    /// The coordinator loop shared by both modes: inject source events at
    /// quiescence barriers, pump the cross-process FIFO, stage shutdown,
    /// collect reports, halt workers.
    fn drive(
        &self,
        topology: &Topology,
        entry: StreamId,
        source: impl Iterator<Item = Event>,
        links: Vec<Link>,
        mut respawn: Option<&mut dyn FnMut(usize) -> Result<Link>>,
        peer_addrs: &[String],
    ) -> Result<(EngineMetrics, Vec<InstanceReport>)> {
        let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
        let n_workers = links.len();
        let mut metrics = EngineMetrics::new(topology.streams.len(), &shape);
        metrics.cluster.workers = n_workers as u64;
        let mut co = Coordinator {
            topology,
            links,
            outstanding: VecDeque::new(),
            rr: vec![0; topology.streams.len()],
            queue: VecDeque::new(),
            delayed: VecDeque::new(),
            metrics,
            window: self.cfg.window.max(1),
            inject: self.cfg.inject_window.max(1),
            buf: Vec::new(),
            recovery_on: self.cfg.checkpoint_every > 0,
            replay_cap: self.cfg.replay_cap.max(1),
            logs: (0..n_workers).map(|_| ReplayLog::new()).collect(),
            store: super::checkpoint::CheckpointStore::new(),
            dead: None,
            respawned: vec![false; n_workers],
            peer: self.cfg.peer,
            peer_off: vec![false; n_workers],
            peer_inflight: vec![vec![0; n_workers]; n_workers],
            peer_lseq: vec![vec![0; n_workers]; n_workers],
            peer_stats: (0..n_workers * n_workers)
                .map(|k| PeerLinkMetrics {
                    from: (k / n_workers) as u32,
                    to: (k % n_workers) as u32,
                    ..Default::default()
                })
                .collect(),
        };
        let started = Instant::now();

        // Peer mode: broadcast the routing table as the very first frame
        // on every link (slot 0, slot-consuming, no reply). Workers
        // bring up their peer mesh on receipt; from then on, eligible
        // emissions ship worker→worker and only reply descriptors cross
        // the coordinator.
        if self.cfg.peer != PeerMode::Off {
            let mut b = Vec::with_capacity(32 + 20 * topology.streams.len());
            codec::put_u8(&mut b, codec::FRAME_ROUTES);
            codec::put_u64(&mut b, 0);
            codec::put_u8(&mut b, if self.cfg.peer == PeerMode::Deterministic { 1 } else { 2 });
            codec::put_u8(&mut b, u8::from(co.recovery_on));
            codec::put_u16(&mut b, n_workers as u16);
            codec::put_u32(&mut b, topology.streams.len() as u32);
            for (s, def) in topology.streams.iter().enumerate() {
                codec::put_u16(&mut b, def.to.0 as u16);
                codec::put_u8(&mut b, grouping_code(def.grouping));
                codec::put_u32(&mut b, def.delay as u32);
                // rr-cursor seed: workers route shuffle streams locally
                // from this cursor; the coordinator mirrors every advance
                // (descriptor replies + its own routes) so the seed is
                // live, not reserved. Always 0 at startup today, but a
                // respawn-era rebroadcast would carry the current value.
                codec::put_u64(&mut b, co.rr[s] as u64);
                // Peer-route eligibility: shuffle at parallelism > 1 is
                // safe only when exactly one emitter feeds the stream
                // (the coordinator's mirror cannot interleave multiple
                // workers' cursor advances deterministically otherwise).
                let sole_emitter =
                    def.from.map_or(false, |p| topology.processors[p.0].parallelism == 1);
                let par = topology.processors[def.to.0].parallelism;
                let eligible = matches!(def.grouping, Grouping::Shuffle)
                    && par > 1
                    && def.delay == 0
                    && sole_emitter;
                codec::put_u8(&mut b, u8::from(eligible));
            }
            codec::put_u16(&mut b, peer_addrs.len() as u16);
            for a in peer_addrs {
                codec::put_u16(&mut b, a.len() as u16);
                b.extend_from_slice(a.as_bytes());
            }
            for w in 0..n_workers {
                let link = &mut co.links[w];
                let wseq = link.wseq;
                link.wseq += 1;
                crate::ensure!(wseq == 0, "cluster: FRAME_ROUTES must be the first frame");
                link.send(&b, true, &mut co.metrics.cluster)?;
                link.flush(&mut co.metrics.cluster)?;
            }
        }

        // A worker death surfaces as an IO error with `co.dead` naming
        // the worker. In recovery mode the loop repairs it in place —
        // once per worker per run — and retries the cascade; outside
        // recovery mode (or during shutdown/collect, a documented
        // non-goal) the error is fatal as before.
        //
        // Pipelined injection: up to `inject_window` source events are
        // routed per quiescence barrier, so the pump sees runs of
        // same-worker deliveries it can coalesce into FRAME_INJECT
        // batches. At the default window of 1 this is exactly the
        // classic inject-drain-inject loop.
        let inject = self.cfg.inject_window.max(1);
        let every = self.cfg.checkpoint_every;
        let mut source = source;
        loop {
            let batch_start = co.metrics.source_instances;
            let mut injected = 0usize;
            while injected < inject {
                let Some(event) = source.next() else { break };
                co.metrics.source_instances += 1;
                let now = co.metrics.source_instances;
                co.release_delayed(now);
                co.route_emission(entry, 0, event, now);
                injected += 1;
            }
            if injected == 0 {
                break;
            }
            let now = co.metrics.source_instances;
            // Checkpoint when the batch crossed a multiple of `every`
            // (reduces to `now % every == 0` at inject_window 1).
            let ckpt = co.recovery_on && now / every > batch_start / every;
            let step = |co: &mut Coordinator| {
                co.pump(now)?;
                if ckpt {
                    co.checkpoint_round()?;
                }
                Ok(())
            };
            let mut res: Result<()> = step(&mut co);
            while let Err(e) = res {
                let w = match co.dead.take() {
                    Some(w) => w,
                    None => return Err(e),
                };
                if !co.recovery_on || co.respawned[w] {
                    return Err(e);
                }
                let rs = match respawn {
                    Some(ref mut rs) => rs,
                    None => return Err(e),
                };
                co.respawned[w] = true;
                co.recover_worker(w, &mut **rs, now)
                    .with_context(|| format!("cluster: recovering dead worker {w}"))?;
                res = step(&mut co);
            }
        }

        // Flush delayed, then staged deterministic shutdown: per
        // processor in pid order, per instance, on_shutdown over the
        // control lane + drain to cross-process quiescence in between.
        let fin = u64::MAX;
        co.release_all_delayed();
        co.pump(fin)?;
        for (p, &par) in shape.iter().enumerate() {
            for i in 0..par {
                let w = worker_of(i, n_workers);
                let link = &mut co.links[w];
                let wseq = link.wseq;
                link.wseq += 1;
                let mut b = Vec::with_capacity(16);
                codec::put_u8(&mut b, K_SHUTDOWN);
                codec::put_u64(&mut b, wseq);
                codec::put_u16(&mut b, p as u16);
                codec::put_u16(&mut b, i as u16);
                link.send(&b, true, &mut co.metrics.cluster)?;
                let pend = Pending {
                    worker: w,
                    wseq,
                    data: false,
                    count: 1,
                    link: None,
                    peer_key: None,
                    log_ref: None,
                    discard: false,
                };
                co.outstanding.push_back(pend);
                co.release_all_delayed();
                co.pump(fin)?;
            }
        }

        // Collect per-instance reports, then halt, worker by worker.
        let mut reports = Vec::new();
        let mut buf = Vec::new();
        for w in 0..n_workers {
            let link = &mut co.links[w];
            let wseq = link.wseq;
            link.wseq += 1;
            let mut b = Vec::with_capacity(9);
            codec::put_u8(&mut b, K_COLLECT);
            codec::put_u64(&mut b, wseq);
            link.send(&b, true, &mut co.metrics.cluster)?;
            link.flush(&mut co.metrics.cluster)?;
            loop {
                co.links[w].read_reply(&mut buf, &mut co.metrics.cluster)?;
                let mut r = Reader::new(&buf);
                match r.u8()? {
                    K_REPORT => {
                        let _wseq = r.u64()?;
                        let pid = r.u16()? as usize;
                        let iid = r.u16()? as usize;
                        let mem_bytes = r.u64()?;
                        let processed = r.u64()?;
                        let busy_ns = r.u64()?;
                        let n_kv = r.u16()?;
                        let mut kv = Vec::with_capacity(n_kv as usize);
                        for _ in 0..n_kv {
                            let ln = r.u16()? as usize;
                            let name = String::from_utf8_lossy(r.bytes(ln)?).into_owned();
                            kv.push((name, r.f64()?));
                        }
                        crate::ensure!(
                            pid < shape.len() && iid < shape[pid],
                            "cluster: report for unknown instance ({pid},{iid})"
                        );
                        co.metrics.per_instance[pid][iid].events_processed = processed;
                        co.metrics.per_instance[pid][iid].busy_ns = busy_ns;
                        reports.push(InstanceReport { pid, iid, mem_bytes, kv });
                    }
                    K_DONE => break,
                    k => crate::bail!("cluster: unexpected report frame kind {k}"),
                }
            }
            let link = &mut co.links[w];
            let wseq = link.wseq;
            link.wseq += 1;
            let mut b = Vec::with_capacity(9);
            codec::put_u8(&mut b, K_HALT);
            codec::put_u64(&mut b, wseq);
            link.send(&b, true, &mut co.metrics.cluster)?;
            link.flush(&mut co.metrics.cluster)?;
        }

        co.metrics.wall_ns = started.elapsed().as_nanos() as u64;
        // Compact the flat n×n link counters down to the links that saw
        // traffic (or stalls) — what `samoa exp cluster` tabulates.
        co.metrics.cluster.peer_links = co
            .peer_stats
            .iter()
            .filter(|l| l.frames > 0 || l.stalls > 0)
            .cloned()
            .collect();
        reports.sort_by_key(|r| (r.pid, r.iid));
        Ok((co.metrics, reports))
    }
}

/// Entry point of a `--cluster-worker` subprocess (dispatched from
/// `main.rs` before normal command parsing): connect back to the
/// coordinator, rebuild the topology from the spec, serve our shard.
pub fn worker_main(args: &Args) -> Result<()> {
    let addr =
        args.get("cluster-worker").ok_or_else(|| crate::anyhow!("missing --cluster-worker"))?;
    let spec_str =
        args.get("cluster-spec").ok_or_else(|| crate::anyhow!("missing --cluster-spec"))?;
    let index = args.usize("cluster-index", 0);
    let n_workers = args.usize("cluster-workers", 1).max(1);
    let measure = args.flag("cluster-measure");
    let peer = args.flag("cluster-peer");

    // Peer mode: bind our peer listener BEFORE handshaking with the
    // coordinator, so every other worker's dial (triggered by the
    // coordinator's FRAME_ROUTES, which can only follow our handshake)
    // is guaranteed to land in a live backlog — no ordering deadlock.
    let (pinit, peer_addr) = if peer {
        if addr.starts_with("tcp:") {
            let l = TcpListener::bind("127.0.0.1:0").context("cluster worker: bind peer tcp")?;
            let a = format!("tcp:{}", l.local_addr()?);
            (PeerInit::Listen(PeerListener::Tcp(l)), a)
        } else {
            let path = std::env::temp_dir()
                .join(format!("samoa-peer-{}-{index}.sock", std::process::id()));
            let _ = std::fs::remove_file(&path);
            let l = UnixListener::bind(&path)
                .with_context(|| format!("cluster worker: bind {}", path.display()))?;
            let a = format!("unix:{}", path.display());
            (PeerInit::Listen(PeerListener::Unix(l, path)), a)
        }
    } else {
        (PeerInit::Off, String::new())
    };

    let connect = |lane: u8| -> Result<Sock> {
        let mut s = if let Some(p) = addr.strip_prefix("unix:") {
            Sock::Unix(UnixStream::connect(p).with_context(|| format!("connect {p}"))?)
        } else if let Some(a) = addr.strip_prefix("tcp:") {
            Sock::Tcp(TcpStream::connect(a).with_context(|| format!("connect {a}"))?)
        } else {
            crate::bail!("cluster worker: bad address {addr}");
        };
        s.write_all(&[index as u8, lane])?;
        s.flush()?;
        Ok(s)
    };
    let mut ctrl = connect(0)?;
    if peer {
        // Announce where our peer listener lives, straight after the
        // control-lane handshake; the coordinator folds all addresses
        // into the FRAME_ROUTES broadcast.
        let mut b = Vec::with_capacity(9 + peer_addr.len());
        codec::put_u8(&mut b, codec::FRAME_PEER_ADDR);
        codec::put_u64(&mut b, 0);
        b.extend_from_slice(peer_addr.as_bytes());
        write_frame(&mut ctrl, &b)?;
        ctrl.flush()?;
    }
    let data = connect(1)?;

    let (topology, _entry) = spec::build(spec_str)?;
    let shape: Vec<usize> = topology.processors.iter().map(|p| p.parallelism).collect();
    let mut owned = Vec::new();
    for (p, def) in topology.processors.iter().enumerate() {
        for i in 0..def.parallelism {
            if worker_of(i, n_workers) == index {
                owned.push((p, i, (def.factory)(i)));
            }
        }
    }
    serve(ctrl, data, owned, shape, measure, index, pinit)
}

pub mod spec {
    //! Topology spec registry for subprocess mode: worker processes
    //! cannot receive processor factories (closures don't cross `exec`),
    //! so coordinator and workers independently rebuild the same topology
    //! from a deterministic spec string `name:key=value:...`. Evaluator
    //! state stays worker-side and returns via [`Processor::report`].

    use super::*;
    use crate::topology::{Grouping, TopologyBuilder};

    /// A sink that counts deliveries and emits nothing — the null
    /// round-trip workload of the `samoa exp cluster` cost sweep. With
    /// `die_at` set (`die=`/`victim=` spec params) it panics on its Nth
    /// delivery — the fault-injection workload of `samoa exp recovery`.
    struct NullSink {
        seen: u64,
        die_at: Option<u64>,
        /// One shot per `build()`: a thread-mode respawn reuses the same
        /// factory in the same process, and the restored `seen` is below
        /// `die_at`, so without this latch the replacement would re-cross
        /// the threshold during replay and die forever. (Subprocess
        /// respawns don't need it — the coordinator strips the fault from
        /// the spec — but the latch makes both modes safe.)
        fired: std::sync::Arc<std::sync::atomic::AtomicBool>,
    }

    impl Processor for NullSink {
        fn process(&mut self, _event: Event, _ctx: &mut Ctx) {
            self.seen += 1;
            if self.die_at == Some(self.seen)
                && !self.fired.swap(true, std::sync::atomic::Ordering::Relaxed)
            {
                panic!("null-sink: injected fault at event {}", self.seen);
            }
        }

        fn name(&self) -> &'static str {
            "null-sink"
        }

        fn report(&self) -> Vec<(&'static str, f64)> {
            vec![("seen", self.seen as f64)]
        }

        fn snapshot(&self) -> Option<Vec<u8>> {
            use crate::engine::checkpoint::{encode_frame, TAG_META_BASE};
            Some(encode_frame(&[(TAG_META_BASE, vec![self.seen as f64])]))
        }

        fn restore(&mut self, frame: &[u8]) -> Result<()> {
            use crate::engine::checkpoint::{decode_frame, section, TAG_META_BASE};
            let sections = decode_frame(frame)?;
            let meta = section(&sections, TAG_META_BASE)
                .ok_or_else(|| crate::anyhow!("null-sink frame: missing meta section"))?;
            crate::ensure!(meta.len() == 1, "null-sink frame: bad meta length");
            self.seen = meta[0] as u64;
            Ok(())
        }
    }

    /// Middle stage of the `relay` spec: forwards every instance keyed
    /// by its id, so the downstream Key stream carries real peer-plane
    /// traffic (unlike `null`, whose only stream is the entry Shuffle —
    /// coordinator-routed by definition).
    struct RelayFwd {
        out: StreamId,
        relayed: u64,
    }

    impl Processor for RelayFwd {
        fn process(&mut self, e: Event, ctx: &mut Ctx) {
            if let Event::Instance { id, inst } = e {
                self.relayed += 1;
                ctx.emit(self.out, id, Event::Instance { id, inst });
            }
        }

        fn name(&self) -> &'static str {
            "relay-fwd"
        }

        fn report(&self) -> Vec<(&'static str, f64)> {
            vec![("relayed", self.relayed as f64)]
        }

        fn snapshot(&self) -> Option<Vec<u8>> {
            use crate::engine::checkpoint::{encode_frame, TAG_META_BASE};
            Some(encode_frame(&[(TAG_META_BASE, vec![self.relayed as f64])]))
        }

        fn restore(&mut self, frame: &[u8]) -> Result<()> {
            use crate::engine::checkpoint::{decode_frame, section, TAG_META_BASE};
            let sections = decode_frame(frame)?;
            let meta = section(&sections, TAG_META_BASE)
                .ok_or_else(|| crate::anyhow!("relay-fwd frame: missing meta section"))?;
            crate::ensure!(meta.len() == 1, "relay-fwd frame: bad meta length");
            self.relayed = meta[0] as u64;
            Ok(())
        }
    }

    fn param(spec: &str, key: &str) -> Option<String> {
        spec.split(':').skip(1).find_map(|kv| {
            kv.split_once('=').and_then(|(k, v)| (k == key).then(|| v.to_string()))
        })
    }

    fn usize_param(spec: &str, key: &str, default: usize) -> usize {
        param(spec, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64_param(spec: &str, key: &str, default: u64) -> u64 {
        param(spec, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The spec with fault-injection params (`die=`, `victim=`) removed —
    /// what the coordinator hands a *respawned* worker, so an injected
    /// bomb cannot re-arm and re-fire during replay.
    pub fn strip_fault(spec: &str) -> String {
        spec.split(':')
            .filter(|seg| !seg.starts_with("die=") && !seg.starts_with("victim="))
            .collect::<Vec<_>>()
            .join(":")
    }

    /// Build the topology named by `spec`. Must be bit-deterministic
    /// given the spec string: the coordinator uses it for routing shape
    /// and every worker rebuilds it to own its instance shard.
    pub fn build(spec: &str) -> Result<(Topology, StreamId)> {
        let name = spec.split(':').next().unwrap_or("");
        match name {
            // null:p=K[:die=N:victim=I] — entry --shuffle--> sink×K, no
            // emissions; instance I panics on its Nth delivery if die>0.
            "null" => {
                let p = usize_param(spec, "p", 2);
                let die = u64_param(spec, "die", 0);
                let victim = usize_param(spec, "victim", 0);
                let mut b = TopologyBuilder::new("cluster-null");
                let fired = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
                let sink = b.add_processor("sink", p, move |i| {
                    let die_at = (die > 0 && i == victim).then_some(die);
                    Box::new(NullSink { seen: 0, die_at, fired: std::sync::Arc::clone(&fired) })
                });
                let entry = b.stream("entry", None, sink, Grouping::Shuffle);
                Ok((b.build(), entry))
            }
            // relay:p=K[:die=N:victim=I][:g=key|shuffle] — entry --shuffle--> fwd(p=1)
            // --key--> sink×K. The fwd→sink Key stream is peer-eligible,
            // so under `--peer` this spec carries worker↔worker traffic
            // (including to a dying victim — the recovery-smoke workload).
            "relay" => {
                let p = usize_param(spec, "p", 2);
                let die = u64_param(spec, "die", 0);
                let victim = usize_param(spec, "victim", 0);
                // g=shuffle swaps the fwd→sink grouping: fwd has
                // parallelism 1 (sole emitter), so the shuffle stream is
                // peer-eligible via the seeded rr cursor under `--peer`.
                let g = match param(spec, "g").as_deref() {
                    None | Some("key") => Grouping::Key,
                    Some("shuffle") => Grouping::Shuffle,
                    Some(other) => crate::bail!("cluster spec: unknown relay grouping '{other}'"),
                };
                let mut b = TopologyBuilder::new("cluster-relay");
                let fired = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
                let fwd = b.add_processor("fwd", 1, |_| {
                    Box::new(RelayFwd { out: StreamId(1), relayed: 0 })
                });
                let sink = b.add_processor("sink", p, move |i| {
                    let die_at = (die > 0 && i == victim).then_some(die);
                    Box::new(NullSink { seen: 0, die_at, fired: std::sync::Arc::clone(&fired) })
                });
                let entry = b.stream("entry", None, fwd, Grouping::Shuffle);
                b.stream("fwd->sink", Some(fwd), sink, g);
                Ok((b.build(), entry))
            }
            // vht:stream=S:p=K:seed=N — the paper's VHT classifier over a
            // dataset twin; accuracy returns via the evaluator's report.
            "vht" => {
                let stream = param(spec, "stream").unwrap_or_else(|| "elec".to_string());
                let p = usize_param(spec, "p", 2);
                let seed = u64_param(spec, "seed", 42);
                let schema = crate::experiments::dataset_stream(&stream, seed).schema().clone();
                let config = crate::classifiers::vht::VhtConfig {
                    parallelism: p,
                    ..Default::default()
                };
                let n_classes = schema.n_classes();
                let (topo, handles) =
                    crate::classifiers::vht::build_topology(&schema, &config, move |_| {
                        let sink =
                            crate::evaluation::prequential::EvalSink::new(n_classes, 1.0, u64::MAX);
                        Box::new(crate::evaluation::prequential::EvaluatorProcessor { sink })
                    });
                Ok((topo, handles.entry))
            }
            // sync:stream=S:p=K:interval=I:seed=N — pipeline shards with
            // exact StatsSync rounds feeding a Hoeffding tree.
            "sync" => {
                let stream = param(spec, "stream").unwrap_or_else(|| "elec".to_string());
                let p = usize_param(spec, "p", 4);
                let interval = u64_param(spec, "interval", 64);
                let seed = u64_param(spec, "seed", 42);
                let schema = crate::experiments::dataset_stream(&stream, seed).schema().clone();
                let n_classes = schema.n_classes();
                let (topo, handles) = crate::preprocess::processor::build_prequential_topology_head(
                    &schema,
                    p,
                    Some(crate::preprocess::SyncPolicy::Count(interval)),
                    |_| {
                        crate::preprocess::Pipeline::new()
                            .then(crate::preprocess::StandardScaler::new())
                    },
                    crate::preprocess::processor::LearnerHead::Classifier(Box::new(
                        |s: &crate::core::Schema| -> Box<dyn crate::core::model::Classifier> {
                            Box::new(crate::classifiers::hoeffding_tree::HoeffdingTree::new(
                                s.clone(),
                                crate::classifiers::hoeffding_tree::HTConfig::default(),
                            ))
                        },
                    )),
                    move |_| {
                        let sink =
                            crate::evaluation::prequential::EvalSink::new(n_classes, 1.0, u64::MAX);
                        Box::new(crate::evaluation::prequential::EvaluatorProcessor { sink })
                    },
                );
                Ok((topo, handles.entry))
            }
            other => crate::bail!("cluster spec: unknown topology '{other}' in '{spec}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::instance::{Instance, Label};
    use crate::topology::{Grouping, TopologyBuilder};

    struct Forwarder {
        out: Option<StreamId>,
        seen: u64,
    }

    impl Processor for Forwarder {
        fn process(&mut self, e: Event, ctx: &mut Ctx) {
            self.seen += 1;
            if let (Some(s), Event::Instance { id, inst }) = (self.out, e) {
                ctx.emit(s, id, Event::Instance { id, inst });
            }
        }

        fn report(&self) -> Vec<(&'static str, f64)> {
            vec![("seen", self.seen as f64)]
        }
    }

    fn inst_event(id: u64) -> Event {
        Event::Instance { id, inst: Instance::dense(vec![id as f32], Label::None) }
    }

    fn two_stage() -> (Topology, StreamId) {
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("a", 1, |_| {
            Box::new(Forwarder { out: Some(StreamId(1)), seen: 0 })
        });
        let c = b.add_processor("c", 3, |_| Box::new(Forwarder { out: None, seen: 0 }));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        b.stream("a->c", Some(a), c, Grouping::Key);
        (b.build(), entry)
    }

    #[test]
    fn pipeline_counts_match_local() {
        let (topo, entry) = two_stage();
        let run = ClusterEngine::new()
            .with_workers(2)
            .run(&topo, entry, (0..100).map(inst_event))
            .expect("cluster run");
        assert_eq!(run.metrics.source_instances, 100);
        assert_eq!(run.metrics.streams[0].events, 100);
        assert_eq!(run.metrics.streams[1].events, 100);
        assert_eq!(run.kv(0, 0, "seen"), Some(100.0));
        let downstream: f64 = (0..3).map(|i| run.kv(1, i, "seen").unwrap()).sum();
        assert_eq!(downstream, 100.0);
        // every delivery crossed a socket and was acknowledged
        assert_eq!(run.metrics.cluster.workers, 2);
        assert!(run.metrics.cluster.data_frames >= 200);
        assert!(run.metrics.cluster.tx_bytes > 0 && run.metrics.cluster.rx_bytes > 0);
    }

    #[test]
    fn stream_totals_bit_identical_to_local() {
        let (topo, entry) = two_stage();
        let local = super::super::LocalEngine::new().run(
            &topo,
            entry,
            (0..257).map(inst_event),
            |_| {},
        );
        let (topo2, entry2) = two_stage();
        for workers in [1, 2, 4] {
            let run = ClusterEngine::new()
                .with_workers(workers)
                .run(&topo2, entry2, (0..257).map(inst_event))
                .expect("cluster run");
            for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
                assert_eq!(a.events, b.events, "stream {s} events at workers={workers}");
                assert_eq!(a.bytes, b.bytes, "stream {s} bytes at workers={workers}");
            }
        }
    }

    #[test]
    fn tiny_window_applies_backpressure_and_stays_exact() {
        let (topo, entry) = two_stage();
        let run = ClusterEngine::new()
            .with_workers(2)
            .with_window(1)
            .run(&topo, entry, (0..64).map(inst_event))
            .expect("cluster run");
        assert_eq!(run.metrics.streams[1].events, 64);
        assert!(run.metrics.flow.backpressure_stalls > 0, "window=1 must stall");
    }

    /// Like `two_stage`, but the second hop is `Grouping::All`: every
    /// forwarded event fans out to all three sinks at once, so a tiny
    /// in-flight window provably stalls (three deliveries are queued
    /// before any reply can be consumed).
    fn fan_out() -> (Topology, StreamId) {
        let mut b = TopologyBuilder::new("t");
        let a = b.add_processor("a", 1, |_| {
            Box::new(Forwarder { out: Some(StreamId(1)), seen: 0 })
        });
        let c = b.add_processor("c", 3, |_| Box::new(Forwarder { out: None, seen: 0 }));
        let entry = b.stream("src", None, a, Grouping::Shuffle);
        b.stream("a->c", Some(a), c, Grouping::All);
        (b.build(), entry)
    }

    #[test]
    fn peer_det_ships_worker_to_worker_bit_identically() {
        let (topo, entry) = two_stage();
        let local = super::super::LocalEngine::new().run(
            &topo,
            entry,
            (0..257).map(inst_event),
            |_| {},
        );
        let (topo2, entry2) = two_stage();
        for workers in [1, 2, 4] {
            let run = ClusterEngine::new()
                .with_workers(workers)
                .with_peer(PeerMode::Deterministic)
                .run(&topo2, entry2, (0..257).map(inst_event))
                .expect("peer cluster run");
            for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
                assert_eq!(a.events, b.events, "stream {s} events at workers={workers}");
                assert_eq!(a.bytes, b.bytes, "stream {s} bytes at workers={workers}");
            }
            assert_eq!(run.kv(0, 0, "seen"), Some(257.0), "workers={workers}");
            let downstream: f64 = (0..3).map(|i| run.kv(1, i, "seen").unwrap()).sum();
            assert_eq!(downstream, 257.0, "workers={workers}");
            // The a->c Key hop rides the peer plane: the coordinator's
            // data lane carries exactly the 257 source injections.
            assert_eq!(run.metrics.cluster.data_frames, 257, "workers={workers}");
            assert_eq!(run.metrics.cluster.peer_frames(), 257, "workers={workers}");
            assert!(!run.metrics.cluster.peer_links.is_empty(), "workers={workers}");
            assert!(run.metrics.cluster.sched_frames > 0, "workers={workers}");
            let link_frames: u64 =
                run.metrics.cluster.peer_links.iter().map(|l| l.frames).sum();
            assert_eq!(link_frames, 257, "workers={workers}");
        }
    }

    #[test]
    fn peer_fast_conserves_stream_totals() {
        let (topo, entry) = two_stage();
        let local = super::super::LocalEngine::new().run(
            &topo,
            entry,
            (0..257).map(inst_event),
            |_| {},
        );
        let (topo2, entry2) = two_stage();
        for workers in [1, 2, 4] {
            let run = ClusterEngine::new()
                .with_workers(workers)
                .with_peer(PeerMode::Fast)
                .run(&topo2, entry2, (0..257).map(inst_event))
                .expect("fast peer cluster run");
            for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
                assert_eq!(a.events, b.events, "stream {s} events at workers={workers}");
                assert_eq!(a.bytes, b.bytes, "stream {s} bytes at workers={workers}");
            }
            let downstream: f64 = (0..3).map(|i| run.kv(1, i, "seen").unwrap()).sum();
            assert_eq!(downstream, 257.0, "workers={workers}");
            assert_eq!(run.metrics.cluster.peer_frames(), 257, "workers={workers}");
        }
    }

    #[test]
    fn peer_tiny_window_stalls_per_link_and_stays_exact() {
        let (topo, entry) = fan_out();
        let local = super::super::LocalEngine::new().run(
            &topo,
            entry,
            (0..64).map(inst_event),
            |_| {},
        );
        let (topo2, entry2) = fan_out();
        let run = ClusterEngine::new()
            .with_workers(2)
            .with_window(1)
            .with_peer(PeerMode::Deterministic)
            .run(&topo2, entry2, (0..64).map(inst_event))
            .expect("peer cluster run");
        assert_eq!(run.metrics.streams[1].events, 192);
        for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
            assert_eq!(a.events, b.events, "stream {s} events");
            assert_eq!(a.bytes, b.bytes, "stream {s} bytes");
        }
        // Each fan-out queues two markers for worker 0's self-link in one
        // pump round; window=1 forces the second to wait for the first.
        assert!(run.metrics.flow.peer_link_stalls > 0, "window=1 must stall peer links");
        let link_stalls: u64 = run.metrics.cluster.peer_links.iter().map(|l| l.stalls).sum();
        assert_eq!(link_stalls, run.metrics.flow.peer_link_stalls);
    }

    #[test]
    fn relay_spec_carries_peer_traffic() {
        let (topo, entry) = spec::build("relay:p=2").expect("relay spec");
        let run = ClusterEngine::new()
            .with_workers(2)
            .with_peer(PeerMode::Deterministic)
            .run(&topo, entry, (0..100).map(inst_event))
            .expect("peer cluster run");
        assert_eq!(run.kv(0, 0, "relayed"), Some(100.0));
        let downstream: f64 = (0..2).map(|i| run.kv(1, i, "seen").unwrap()).sum();
        assert_eq!(downstream, 100.0);
        // entry injections are the only coordinator data-lane traffic;
        // every fwd->sink delivery went worker->worker.
        assert_eq!(run.metrics.cluster.data_frames, 100);
        assert_eq!(run.metrics.cluster.peer_frames(), 100);
    }

    #[test]
    fn inject_window_batches_data_frames_and_stays_exact() {
        let (topo, entry) = two_stage();
        let local = super::super::LocalEngine::new().with_inject_window(8).run(
            &topo,
            entry,
            (0..257).map(inst_event),
            |_| {},
        );
        let (topo2, entry2) = two_stage();
        let run = ClusterEngine::new()
            .with_workers(2)
            .with_inject_window(8)
            .run(&topo2, entry2, (0..257).map(inst_event))
            .expect("cluster run");
        for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
            assert_eq!(a.events, b.events, "stream {s} events");
            assert_eq!(a.bytes, b.bytes, "stream {s} bytes");
        }
        assert_eq!(run.kv(0, 0, "seen"), Some(257.0));
        let downstream: f64 = (0..3).map(|i| run.kv(1, i, "seen").unwrap()).sum();
        assert_eq!(downstream, 257.0);
        // Per-event shipping would cost 514 data frames (257 source +
        // 257 a->c); batching coalesces same-worker runs.
        assert!(run.metrics.flow.inject_frames > 0, "expected FRAME_INJECT batches");
        assert!(run.metrics.flow.inject_events > 0);
        assert!(
            run.metrics.cluster.data_frames < 514,
            "batched run still shipped {} data frames",
            run.metrics.cluster.data_frames
        );
    }

    #[test]
    fn relay_shuffle_peer_routes_with_seeded_cursor() {
        // g=shuffle at p=2 with a sole emitter: the fwd worker routes
        // via its seeded rr cursor and ships peer-to-peer; the split is
        // the local engine's deterministic round-robin (50/50).
        let (topo, entry) = spec::build("relay:p=2:g=shuffle").expect("relay spec");
        let local = super::super::LocalEngine::new().run(
            &topo,
            entry,
            (0..100).map(inst_event),
            |_| {},
        );
        let (topo2, entry2) = spec::build("relay:p=2:g=shuffle").expect("relay spec");
        let run = ClusterEngine::new()
            .with_workers(2)
            .with_peer(PeerMode::Deterministic)
            .run(&topo2, entry2, (0..100).map(inst_event))
            .expect("peer cluster run");
        for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
            assert_eq!(a.events, b.events, "stream {s} events");
            assert_eq!(a.bytes, b.bytes, "stream {s} bytes");
        }
        assert_eq!(run.kv(0, 0, "relayed"), Some(100.0));
        assert_eq!(run.kv(1, 0, "seen"), Some(50.0));
        assert_eq!(run.kv(1, 1, "seen"), Some(50.0));
        // The shuffle hop rides the peer plane, not the coordinator.
        assert_eq!(run.metrics.cluster.data_frames, 100);
        assert_eq!(run.metrics.cluster.peer_frames(), 100);
    }

    #[test]
    fn inject_window_with_peer_shuffle_bounds_coordinator_frames() {
        let (topo, entry) = spec::build("relay:p=2:g=shuffle").expect("relay spec");
        let run = ClusterEngine::new()
            .with_workers(2)
            .with_peer(PeerMode::Deterministic)
            .with_inject_window(8)
            .run(&topo, entry, (0..100).map(inst_event))
            .expect("peer cluster run");
        let downstream: f64 = (0..2).map(|i| run.kv(1, i, "seen").unwrap()).sum();
        assert_eq!(downstream, 100.0);
        // All 100 source events target fwd instance 0 (one worker), so
        // every injection barrier ships exactly one FRAME_INJECT batch:
        // ceil(100/8) = 13 coordinator data frames for the whole run.
        assert_eq!(run.metrics.cluster.data_frames, 13);
        assert_eq!(run.metrics.flow.inject_frames, 13);
        assert_eq!(run.metrics.flow.inject_events, 100);
        // The fwd->sink deliveries still all flow worker->worker.
        assert_eq!(run.metrics.cluster.peer_frames(), 100);
    }
}
