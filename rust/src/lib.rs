//! # samoa-rs — Scalable Advanced Massive Online Analysis, in Rust
//!
//! A reproduction of **Apache SAMOA** (Kourtellis, De Francisci Morales,
//! Bifet 2018): a platform for distributed machine learning on data
//! streams, built as a three-layer rust + JAX/Pallas stack.
//!
//! * **L3 (this crate)** — the SAMOA platform: a mini distributed stream
//!   processing engine ([`topology`], [`engine`]) and the paper's algorithm
//!   library: Vertical Hoeffding Tree ([`classifiers::vht`]), distributed
//!   AMRules ([`regressors`]), CluStream ([`clustering`]), ensembles and
//!   drift detectors ([`ensemble`], [`drift`]), plus stream generators
//!   ([`streams`]), a streaming preprocessing & feature-pipeline layer
//!   with sketch-backed operators whose statistics are mergeable and
//!   shard-convergent under parallelism ([`preprocess`]) and prequential
//!   evaluation ([`evaluation`]).
//! * **L2/L1 (python, build-time only)** — the numeric hot-spots
//!   (split-criterion information gain, AMRules SDR, CluStream assignment)
//!   as Pallas kernels under JAX, AOT-lowered to HLO text and executed from
//!   rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once; the rust binary is self-contained after that.
//! Builds without artifacts (or without PJRT bindings at all — the
//! dependency-free default compiles against an in-tree stub) run the same
//! kernels through [`runtime`]'s pure-rust backends: a scalar native twin
//! and a lane-unrolled SIMD variant, selected per process by a one-shot
//! micro-probe or pinned via `SAMOA_BACKEND=native|simd|xla|auto`.

pub mod common;
pub mod topology;
pub mod engine;
pub mod core;
pub mod classifiers;
pub mod regressors;
pub mod clustering;
pub mod drift;
pub mod ensemble;
pub mod streams;
pub mod preprocess;
pub mod evaluation;
pub mod runtime;
pub mod experiments;

/// Crate-wide result type (see [`common::error`] — the in-tree `anyhow`
/// replacement, so the crate has zero external dependencies).
pub type Result<T> = common::error::Result<T>;

pub use common::error::{Context, Error};
