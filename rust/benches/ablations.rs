//! Ablation benches for the design choices DESIGN.md calls out:
//!   1. attribute batching on/off (one message per LS vs per attribute)
//!   2. XLA vs native criterion backend on the same workload
//!   3. info-gain vs gini split criterion (quality + time)
//!   4. grace period n_min sensitivity

mod bench_util;
use bench_util::bench;

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree, LeafPrediction};
use samoa::classifiers::vht::{build_topology, VhtConfig};
use samoa::core::criterion;
use samoa::core::model::Classifier;
use samoa::core::observers::CounterBlock;
use samoa::engine::LocalEngine;
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::experiments::dataset_stream;
use samoa::streams::StreamSource;
use samoa::topology::Event;

fn vht_run(batch: bool, n: u64) -> f64 {
    let mut stream = dataset_stream("covtype", 42);
    let config = VhtConfig { parallelism: 4, batch_attributes: batch, ..Default::default() };
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, n);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    sink.accuracy()
}

fn main() {
    let n = 50_000u64;

    // 1. attribute batching
    let mut accs = (0.0, 0.0);
    bench("ablation: VHT attribute batching ON", 3, || {
        accs.0 = vht_run(true, n);
        n
    });
    bench("ablation: VHT attribute batching OFF", 3, || {
        accs.1 = vht_run(false, n);
        n
    });
    println!(
        "  -> accuracy identical: batched={:.4} unbatched={:.4}",
        accs.0, accs.1
    );
    assert!((accs.0 - accs.1).abs() < 1e-9, "batching must be semantics-preserving");

    // 2. backend: XLA vs native on the sequential tree's split path
    for backend in ["xla", "native"] {
        if backend == "native" {
            samoa::runtime::registry::force_backend(samoa::runtime::Backend::Native);
        }
        bench(&format!("ablation: hoeffding tree, backend={backend}"), 3, || {
            let mut stream = dataset_stream("covtype", 42);
            let mut ht = HoeffdingTree::new(
                stream.schema().clone(),
                HTConfig { leaf_prediction: LeafPrediction::MajorityClass, ..Default::default() },
            );
            for _ in 0..n {
                let Some(i) = stream.next_instance() else { break };
                ht.train(&i);
            }
            n
        });
    }

    // 3. info gain vs gini ordering agreement on random counter tables
    let mut rng = samoa::common::Rng::new(9);
    let blocks: Vec<CounterBlock> = (0..200)
        .map(|_| {
            let mut b = CounterBlock::new(16, 8);
            for _ in 0..300 {
                b.add(rng.below(16) as u32, rng.below(8) as u32, 1.0);
            }
            b
        })
        .collect();
    bench("ablation: info-gain criterion x200 blocks", 10, || {
        std::hint::black_box(blocks.iter().map(criterion::info_gain).sum::<f64>());
        200
    });
    bench("ablation: gini criterion x200 blocks", 10, || {
        std::hint::black_box(blocks.iter().map(criterion::gini_gain).sum::<f64>());
        200
    });

    // 4. grace period sensitivity (splits vs time)
    for gp in [50u32, 200, 800] {
        bench(&format!("ablation: grace period n_min={gp}"), 3, || {
            let mut stream = dataset_stream("covtype", 42);
            let mut ht = HoeffdingTree::new(
                stream.schema().clone(),
                HTConfig { grace_period: gp, ..Default::default() },
            );
            let mut correct = 0u64;
            for _ in 0..n {
                let Some(i) = stream.next_instance() else { break };
                if ht.predict(&i) == i.class() {
                    correct += 1;
                }
                ht.train(&i);
            }
            std::hint::black_box(correct);
            n
        });
    }
}
