//! Bench regenerating Figs 8-9 (VHT wok scaling) at bench scale.

use samoa::common::cli::Args;

fn main() {
    let args = Args::parse(
        ["--instances", "10000", "--seeds", "1"].iter().map(|s| s.to_string()),
    );
    samoa::experiments::run("fig8", &args).unwrap();
    samoa::experiments::run("fig9", &args).unwrap();
}
