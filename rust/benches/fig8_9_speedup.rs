//! Figs 8–9 at bench scale: VHT `wok` speedup by parallelism on the
//! simulated-time engine, with the paper's Storm-like cost model
//! (per-attribute messages, feedback delay so load shedding engages —
//! see `experiments::vht_exps::fig8_9` for the full-fidelity table).
//!
//! Two row families per parallelism, both gate-visible under `fig/`:
//!
//! - `fig/vht_wok p=N` — wall-clock rows from [`bench_util::bench`]
//!   (the engine really runs the topology, so wall items/s is a real
//!   perf signal for the trajectory gate);
//! - `fig/vht_wok_sim p=N` — the simulated-time throughput plus
//!   `speedup_vs_1w`, the reproduction target's scaling shape.
//!
//! The speedup baseline is the same-software single-worker run under
//! the same cost model with no feedback delay, exactly as in the
//! experiment table.

mod bench_util;
use bench_util::{bench, record_json, smoke_mode};

use std::sync::Arc;

use samoa::classifiers::vht::{self, SplitBuffering, VhtConfig};
use samoa::engine::{SimCostModel, SimTimeEngine};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::streams::StreamSource;
use samoa::topology::Event;

/// One simulated run: returns (sim items/s, attribute-stream events).
fn run_sim(cost: SimCostModel, p: usize, delay: usize, n: u64) -> (f64, u64) {
    let mut stream: Box<dyn StreamSource> = samoa::experiments::dataset_stream("elec", 42);
    let config = VhtConfig {
        parallelism: p,
        buffering: SplitBuffering::Discard,
        feedback_delay: delay,
        batch_attributes: false, // per-attribute events, as in Table 2
        ..Default::default()
    };
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, n);
    let (topo, handles) = vht::build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink) })
    });
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let r = SimTimeEngine::new(cost).run(&topo, handles.entry, source, |_| {});
    (r.throughput(), r.metrics.streams[handles.streams.attribute.0].events)
}

fn main() {
    let n: u64 = if smoke_mode() { 2_000 } else { 10_000 };
    let ps: &[usize] = if smoke_mode() { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let delay = 100usize;
    // Storm-like per-tuple costs (the paper ran VHT on Storm 0.9.3).
    let cost = SimCostModel {
        c_msg_ns: 2_000.0,
        c_byte_ns: 2.0,
        tx_frac: 0.25,
        ..SimCostModel::default()
    };
    println!("== fig 8/9 bench: VHT wok scaling (elec twin, {n} inst) ==");

    // Same-software, same-cost-model baseline: single worker, no delay.
    let (base_tput, _) = run_sim(cost, 1, 0, n);

    let mut rows: Vec<(usize, f64, f64, u64)> = Vec::new();
    for &p in ps {
        let mut sim_tput = 0.0f64;
        let mut attr_events = 0u64;
        bench(&format!("fig/vht_wok p={p}"), 3, || {
            let (t, a) = run_sim(cost, p, delay, n);
            sim_tput = t;
            attr_events = a;
            n
        });
        let speedup = sim_tput / base_tput.max(1e-9);
        record_json(
            &format!("fig/vht_wok_sim p={p}"),
            &[("items_per_s", sim_tput), ("speedup_vs_1w", speedup)],
        );
        rows.push((p, sim_tput, speedup, attr_events));
    }

    println!("\n{:<6} {:>16} {:>14} {:>14}", "p", "sim inst/s", "speedup vs 1w", "attr events");
    for (p, tput, speedup, attr) in &rows {
        println!("{p:<6} {tput:>16.0} {speedup:>13.2}x {attr:>14}");
    }
    // The scaling *shape* is the target: more workers must not price the
    // topology slower than the 1-worker run under the same cost model.
    let (_, t1, _, _) = rows[0];
    let &(pmax, tmax, _, _) = rows.last().unwrap();
    assert!(
        tmax >= t1 * 0.9,
        "fig8/9 bench: wok at p={pmax} simulated {tmax:.0} inst/s, \
         below 0.9x the p=1 run ({t1:.0})"
    );
}
