//! Zero-copy data-plane bench: engine events/sec across
//!
//! * engine: local vs threaded,
//! * parallelism p ∈ {1, 2, 4, 8},
//! * payload: dense (256 × f32, ≈1 KB — the paper's Fig. 13 reference
//!   size) vs sparse (32 of 1000 attributes),
//! * topology: broadcast-heavy (`All`, the ensemble shape) vs key-grouped
//!   (`Key`, the VHT shape),
//!
//! with **both data planes** recorded per configuration:
//!
//! * `baseline` — the pre-refactor semantics: deep-copied payload per
//!   broadcast delivery (`Event::deep_clone`) and, on the threaded
//!   engine, per-event channel sends (`batch_size = 1`);
//! * `zerocopy` — Arc-shared clones + micro-batched channels (the
//!   defaults).
//!
//! The final summary line reports the speedup on the acceptance
//! configuration (threaded, broadcast, p = 4): the zero-copy plane must
//! beat the committed baseline there.

mod bench_util;
use bench_util::{bench, smoke_mode};

use samoa::core::instance::{Instance, Label};
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::topology::{Ctx, Event, Grouping, Processor, TopologyBuilder};

struct Nop;
impl Processor for Nop {
    fn process(&mut self, _e: Event, _c: &mut Ctx) {}
}

fn make_event(id: u64, sparse: bool) -> Event {
    let inst = if sparse {
        // 32 non-zeros out of 1000 attributes (tweet-like)
        let indices: Vec<u32> = (0..32u32).map(|i| i * 31).collect();
        Instance::sparse(indices, vec![1.0; 32], 1000, Label::Class(0))
    } else {
        Instance::dense(vec![0.5; 256], Label::Class(0))
    };
    Event::Instance { id, inst }
}

#[derive(Clone, Copy)]
struct Config {
    threaded: bool,
    p: usize,
    sparse: bool,
    broadcast: bool,
    /// Pre-refactor baseline: deep-copy broadcasts + unbatched channels.
    baseline: bool,
}

/// One run; returns events/sec over `n` source events.
fn run(cfg: Config, n: u64) -> f64 {
    let mut b = TopologyBuilder::new("tp");
    let w = b.add_processor("w", cfg.p, |_| Box::new(Nop));
    let grouping = if cfg.broadcast { Grouping::All } else { Grouping::Key };
    let entry = b.stream("in", None, w, grouping);
    let topo = b.build();
    let source = (0..n).map(|id| make_event(id, cfg.sparse));
    let t0 = std::time::Instant::now();
    if cfg.threaded {
        let eng = ThreadedEngine {
            queue_capacity: 1024,
            batch_size: if cfg.baseline { 1 } else { 32 },
            deep_copy_broadcast: cfg.baseline,
        };
        eng.run(&topo, entry, source, |_, _, _| {});
    } else {
        let eng = LocalEngine { measure_busy: false, deep_copy_broadcast: cfg.baseline };
        eng.run(&topo, entry, source, |_| {});
    }
    n as f64 / t0.elapsed().as_secs_f64().max(1e-12)
}

fn main() {
    let n: u64 = if smoke_mode() { 4_000 } else { 40_000 };
    println!("== engine_throughput: zero-copy data plane vs deep-copy baseline ==");
    println!("(events/sec of the bench row = source events; broadcast rows deliver p× that)");

    // remembered for the acceptance summary: (baseline, zerocopy) at
    // threaded broadcast dense p=4
    let mut acceptance: (f64, f64) = (0.0, 0.0);

    for threaded in [false, true] {
        for broadcast in [true, false] {
            for sparse in [false, true] {
                for p in [1usize, 2, 4, 8] {
                    let name = format!(
                        "{} {} {} p={p}",
                        if threaded { "threaded" } else { "local" },
                        if broadcast { "broadcast" } else { "key-grouped" },
                        if sparse { "sparse" } else { "dense" },
                    );
                    let mut pair = (0.0f64, 0.0f64);
                    for baseline in [true, false] {
                        let cfg = Config { threaded, p, sparse, broadcast, baseline };
                        let label = format!(
                            "{name} [{}]",
                            if baseline { "baseline" } else { "zerocopy" }
                        );
                        // measure inside bench for the stats row, keep the
                        // median-equivalent single measurement for ratios
                        let mut best = 0.0f64;
                        bench(&label, 3, || {
                            let tput = run(cfg, n);
                            best = best.max(tput);
                            n
                        });
                        if baseline {
                            pair.0 = best;
                        } else {
                            pair.1 = best;
                        }
                    }
                    println!(
                        "  {name}: zerocopy/baseline speedup = {:.2}x",
                        pair.1 / pair.0.max(1e-12)
                    );
                    if threaded && broadcast && !sparse && p == 4 {
                        acceptance = pair;
                    }
                }
            }
        }
    }

    println!(
        "acceptance (threaded broadcast dense p=4): baseline={:.0} ev/s, \
         zerocopy={:.0} ev/s, speedup={:.2}x (target >= 2x)",
        acceptance.0,
        acceptance.1,
        acceptance.1 / acceptance.0.max(1e-12)
    );
}
