//! Data-plane bench: events/sec across engines, payloads and fan-out
//! shapes, plus the flow-control sweep of the elastic threaded plane.
//!
//! **Section 1 — zero-copy plane** (the PR-3 acceptance matrix):
//!
//! * engine: local vs threaded,
//! * parallelism p ∈ {1, 2, 4, 8},
//! * payload: dense (256 × f32, ≈1 KB — the paper's Fig. 13 reference
//!   size) vs sparse (32 of 1000 attributes),
//! * topology: broadcast-heavy (`All`, the ensemble shape) vs key-grouped
//!   (`Key`, the VHT shape),
//!
//! with **both data planes** recorded per configuration: `baseline` =
//! deep-copied broadcasts + per-event sends; `zerocopy` = Arc-shared
//! clones + fixed 32-event micro-batches.
//!
//! **Section 2 — flow control**: capacity × batch-policy × workers on a
//! compute-bound stage, where bounded queues and the scheduler actually
//! bite. The acceptance pair: the adaptive batcher must not lose to
//! fixed `batch=32` at full rate.
//!
//! **Section 3 — delivery latency at low rate**: a trickle source
//! (10 kHz) through fixed-32 vs adaptive batching; adaptive must cut
//! the p50 delivery latency (it shrinks per-edge batches toward 1 and
//! flushes on source idle instead of parking events in a 32-slot
//! buffer).
//!
//! **Section 4 — cluster data plane**: the relay topology (entry →
//! fwd → key-grouped sinks) on the cluster engine with thread-mode
//! workers (subprocess mode would re-exec this bench binary), comparing
//! coordinator-routed delivery against both peer modes; rows are
//! `clu/`-prefixed so the perf gate tracks the socket plane separately.
//! Additional `inj32` rows drive the same workload with pipelined
//! source injection (32 events per quiescence barrier, shipped as
//! `FRAME_INJECT` batches) and the peer-routed Shuffle variant.
//!
//! Every row lands in `BENCH_JSON` as `tput/...` or `clu/...` — the
//! rows the CI perf-trajectory gate (`tools/bench_compare.py`) diffs
//! against the committed `perf/BENCH_PR*.json` history.

mod bench_util;
use bench_util::{bench, record_json, smoke_mode};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use samoa::core::instance::{Instance, Label};
use samoa::engine::cluster::spec as cluster_spec;
use samoa::engine::{ClusterEngine, LocalEngine, PeerMode, ThreadedEngine};
// the same deterministic spin load `samoa exp flowcontrol` sweeps
use samoa::experiments::flowcontrol::Burn;
use samoa::topology::{Ctx, Event, Grouping, Processor, TopologyBuilder};

struct Nop;
impl Processor for Nop {
    fn process(&mut self, _e: Event, _c: &mut Ctx) {}
}

fn make_event(id: u64, sparse: bool) -> Event {
    let inst = if sparse {
        // 32 non-zeros out of 1000 attributes (tweet-like)
        let indices: Vec<u32> = (0..32u32).map(|i| i * 31).collect();
        Instance::sparse(indices, vec![1.0; 32], 1000, Label::Class(0))
    } else {
        Instance::dense(vec![0.5; 256], Label::Class(0))
    };
    Event::Instance { id, inst }
}

#[derive(Clone, Copy)]
struct Config {
    threaded: bool,
    p: usize,
    sparse: bool,
    broadcast: bool,
    /// Pre-refactor baseline: deep-copy broadcasts + unbatched channels.
    baseline: bool,
}

/// One run; returns events/sec over `n` source events.
fn run(cfg: Config, n: u64) -> f64 {
    let mut b = TopologyBuilder::new("tp");
    let w = b.add_processor("w", cfg.p, |_| Box::new(Nop));
    let grouping = if cfg.broadcast { Grouping::All } else { Grouping::Key };
    let entry = b.stream("in", None, w, grouping);
    let topo = b.build();
    let source = (0..n).map(|id| make_event(id, cfg.sparse));
    let t0 = std::time::Instant::now();
    if cfg.threaded {
        let mut eng = ThreadedEngine::new(1024).with_batch(if cfg.baseline { 1 } else { 32 });
        eng.deep_copy_broadcast = cfg.baseline;
        eng.run(&topo, entry, source, |_, _, _| {});
    } else {
        let eng = LocalEngine { deep_copy_broadcast: cfg.baseline, ..LocalEngine::default() };
        eng.run(&topo, entry, source, |_| {});
    }
    n as f64 / t0.elapsed().as_secs_f64().max(1e-12)
}

/// Batch policy of the flow-control sweep.
#[derive(Clone, Copy)]
enum BatchPolicy {
    Fixed(usize),
    Adaptive(usize),
}

impl BatchPolicy {
    fn label(&self) -> String {
        match self {
            BatchPolicy::Fixed(n) => format!("fixed{n}"),
            BatchPolicy::Adaptive(n) => format!("adaptive{n}"),
        }
    }

    fn apply(&self, eng: ThreadedEngine) -> ThreadedEngine {
        match self {
            BatchPolicy::Fixed(n) => eng.with_batch(*n),
            BatchPolicy::Adaptive(n) => eng.with_adaptive_batch(*n),
        }
    }
}

/// One flow-control run: fast source → burn(p=4), key-grouped. Returns
/// (events/sec, stalls, peak queue events, steals).
fn run_flow(
    capacity: usize,
    policy: BatchPolicy,
    workers: Option<usize>,
    n: u64,
) -> (f64, u64, u64, u64) {
    let mut b = TopologyBuilder::new("fc");
    let w = b.add_processor("burn", 4, |_| Box::new(Burn(2_000)));
    let entry = b.stream("in", None, w, Grouping::Key);
    let topo = b.build();
    let mut eng = policy.apply(if capacity == usize::MAX {
        ThreadedEngine::default().unbounded()
    } else {
        ThreadedEngine::new(capacity)
    });
    if let Some(n_workers) = workers {
        eng = eng.with_workers(n_workers);
    }
    let source = (0..n).map(|id| make_event(id, false));
    let t0 = Instant::now();
    let m = eng.run(&topo, entry, source, |_, _, _| {});
    let tput = n as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    (tput, m.flow.backpressure_stalls, m.max_peak_queue_events(), m.flow.steals)
}

/// One cluster-engine run of the relay spec with thread-mode workers;
/// returns (events/sec, coordinator data frames, peer frames).
/// `inject` > 1 batches source events into FRAME_INJECT frames;
/// `shuffle` swaps the fwd→sink hop to peer-routed Shuffle (`g=shuffle`).
fn run_cluster(
    workers: usize,
    peer: PeerMode,
    inject: usize,
    shuffle: bool,
    n: u64,
) -> (f64, u64, u64) {
    let g = if shuffle { ":g=shuffle" } else { "" };
    let (topo, entry) =
        cluster_spec::build(&format!("relay:p={workers}{g}")).expect("relay spec");
    let eng = ClusterEngine::new()
        .with_workers(workers)
        .with_peer(peer)
        .with_inject_window(inject);
    let source = (0..n).map(|id| Event::Instance {
        id,
        inst: Instance::dense(vec![0.25; 8], Label::None),
    });
    let t0 = Instant::now();
    let run = eng.run(&topo, entry, source).expect("cluster run");
    let tput = n as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    assert_eq!(run.kv_sum("seen"), n as f64, "relay sinks must see every instance");
    let c = &run.metrics.cluster;
    (tput, c.data_frames, c.peer_frames())
}

/// Sink that records per-event delivery latency against the send stamps.
struct LatencySink {
    t0: Instant,
    send_ns: Arc<Vec<AtomicU64>>,
    latencies: Arc<Mutex<Vec<u64>>>,
}

impl Processor for LatencySink {
    fn process(&mut self, e: Event, _c: &mut Ctx) {
        if let Event::Instance { id, .. } = e {
            let now = self.t0.elapsed().as_nanos() as u64;
            let sent = self.send_ns[id as usize].load(Ordering::Relaxed);
            self.latencies.lock().unwrap().push(now.saturating_sub(sent));
        }
    }
}

/// Trickle source (gap ≈ 100µs) through the given engine; returns
/// (p50, p95) delivery latency in µs.
fn run_latency(policy: BatchPolicy, n: u64) -> (f64, f64) {
    let t0 = Instant::now();
    let send_ns: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    let latencies: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut b = TopologyBuilder::new("lat");
    let send2 = Arc::clone(&send_ns);
    let lat2 = Arc::clone(&latencies);
    let sink = b.add_processor("sink", 1, move |_| {
        Box::new(LatencySink {
            t0,
            send_ns: Arc::clone(&send2),
            latencies: Arc::clone(&lat2),
        })
    });
    let entry = b.stream("in", None, sink, Grouping::Shuffle);
    let topo = b.build();
    let send3 = Arc::clone(&send_ns);
    let source = (0..n).map(move |id| {
        // gap must sit safely above the engine's ~200µs slow-source
        // threshold, or the adaptive idle-flush never triggers and the
        // probe measures scheduler jitter instead of the feature
        std::thread::sleep(Duration::from_micros(500));
        send3[id as usize].store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Event::Instance { id, inst: Instance::dense(vec![0.5; 8], Label::None) }
    });
    policy
        .apply(ThreadedEngine::new(1024))
        .run(&topo, entry, source, |_, _, _| {});
    let mut lats = latencies.lock().unwrap().clone();
    lats.sort_unstable();
    let pct = |q: f64| -> f64 {
        if lats.is_empty() {
            return f64::NAN;
        }
        lats[((lats.len() - 1) as f64 * q) as usize] as f64 / 1_000.0
    };
    (pct(0.50), pct(0.95))
}

fn main() {
    let n: u64 = if smoke_mode() { 4_000 } else { 40_000 };
    println!("== engine_throughput 1: zero-copy data plane vs deep-copy baseline ==");
    println!("(events/sec of the bench row = source events; broadcast rows deliver p× that)");

    // remembered for the acceptance summary: (baseline, zerocopy) at
    // threaded broadcast dense p=4
    let mut acceptance: (f64, f64) = (0.0, 0.0);

    for threaded in [false, true] {
        for broadcast in [true, false] {
            for sparse in [false, true] {
                for p in [1usize, 2, 4, 8] {
                    let name = format!(
                        "tput/{} {} {} p={p}",
                        if threaded { "threaded" } else { "local" },
                        if broadcast { "broadcast" } else { "key-grouped" },
                        if sparse { "sparse" } else { "dense" },
                    );
                    let mut pair = (0.0f64, 0.0f64);
                    for baseline in [true, false] {
                        let cfg = Config { threaded, p, sparse, broadcast, baseline };
                        let label = format!(
                            "{name} [{}]",
                            if baseline { "baseline" } else { "zerocopy" }
                        );
                        // measure inside bench for the stats row, keep the
                        // median-equivalent single measurement for ratios
                        let mut best = 0.0f64;
                        bench(&label, 3, || {
                            let tput = run(cfg, n);
                            best = best.max(tput);
                            n
                        });
                        if baseline {
                            pair.0 = best;
                        } else {
                            pair.1 = best;
                        }
                    }
                    println!(
                        "  {name}: zerocopy/baseline speedup = {:.2}x",
                        pair.1 / pair.0.max(1e-12)
                    );
                    if threaded && broadcast && !sparse && p == 4 {
                        acceptance = pair;
                    }
                }
            }
        }
    }

    println!(
        "acceptance (threaded broadcast dense p=4): baseline={:.0} ev/s, \
         zerocopy={:.0} ev/s, speedup={:.2}x (target >= 2x)",
        acceptance.0,
        acceptance.1,
        acceptance.1 / acceptance.0.max(1e-12)
    );

    // ------------------------------------------------------------------
    println!("\n== engine_throughput 2: flow-control sweep (capacity × batch × workers) ==");
    println!("(fast source → burn stage p=4, key-grouped; stalls/peak from EngineMetrics)");
    let nf: u64 = if smoke_mode() { 2_000 } else { 20_000 };
    // remembered for the acceptance summary at capacity 1024, pinned
    let (mut hot_fixed32, mut hot_adaptive) = (0.0f64, 0.0f64);
    for capacity in [4usize, 1024, usize::MAX] {
        for policy in [BatchPolicy::Fixed(1), BatchPolicy::Fixed(32), BatchPolicy::Adaptive(32)] {
            for workers in [None, Some(2usize)] {
                let cap_label = if capacity == usize::MAX {
                    "unbounded".to_string()
                } else {
                    format!("cap={capacity}")
                };
                let w_label = workers.map_or("pinned".to_string(), |w| format!("steal{w}"));
                let label = format!("tput/flow {cap_label} {} {w_label}", policy.label());
                let mut last = (0.0, 0, 0, 0);
                bench(&label, 2, || {
                    last = run_flow(capacity, policy, workers, nf);
                    nf
                });
                let (tput, stalls, peak, steals) = last;
                println!(
                    "  {label}: stalls={stalls} peak_queue={peak}ev steals={steals}"
                );
                record_json(
                    &format!("{label} [fc]"),
                    &[
                        ("stalls", stalls as f64),
                        ("peak_queue_events", peak as f64),
                        ("steals", steals as f64),
                    ],
                );
                if capacity == 1024 && workers.is_none() {
                    match policy {
                        BatchPolicy::Fixed(32) => hot_fixed32 = tput,
                        BatchPolicy::Adaptive(32) => hot_adaptive = tput,
                        _ => {}
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    println!("\n== engine_throughput 3: delivery latency at low rate (trickle source) ==");
    let nl: u64 = if smoke_mode() { 100 } else { 600 };
    let (fixed_p50, fixed_p95) = run_latency(BatchPolicy::Fixed(32), nl);
    let (adapt_p50, adapt_p95) = run_latency(BatchPolicy::Adaptive(32), nl);
    println!("  tput/latency fixed32 : p50={fixed_p50:.1}us p95={fixed_p95:.1}us");
    println!("  tput/latency adaptive: p50={adapt_p50:.1}us p95={adapt_p95:.1}us");
    // items_per_s here is the inverse p50 (deliveries/sec at p50 latency):
    // a higher-is-better alias so the CI trajectory gate watches latency
    // regressions with the same >15% rule as the throughput rows
    record_json(
        "tput/latency fixed32",
        &[
            ("p50_us", fixed_p50),
            ("p95_us", fixed_p95),
            ("items_per_s", 1e6 / fixed_p50.max(1e-9)),
        ],
    );
    record_json(
        "tput/latency adaptive",
        &[
            ("p50_us", adapt_p50),
            ("p95_us", adapt_p95),
            ("items_per_s", 1e6 / adapt_p50.max(1e-9)),
        ],
    );

    println!("\n== acceptance: adaptive micro-batching ==");
    let hot_ok = hot_adaptive >= hot_fixed32 * 0.9;
    let lat_ok = adapt_p50 < fixed_p50;
    println!(
        "  high rate : adaptive={hot_adaptive:.0} ev/s vs fixed32={hot_fixed32:.0} ev/s \
         (target >= 0.9x) -> {}",
        if hot_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "  low rate  : adaptive p50={adapt_p50:.1}us vs fixed32 p50={fixed_p50:.1}us \
         (target: lower) -> {}",
        if lat_ok { "PASS" } else { "FAIL" }
    );

    // ------------------------------------------------------------------
    println!("\n== engine_throughput 4: cluster data plane (relay, thread-mode workers) ==");
    println!("(coordinator-routed vs peer worker links; frames from ClusterMetrics)");
    let nc: u64 = if smoke_mode() { 2_000 } else { 10_000 };
    for workers in [2usize, 4] {
        for peer in [PeerMode::Off, PeerMode::Deterministic, PeerMode::Fast] {
            let peer_label = match peer {
                PeerMode::Off => "coord",
                PeerMode::Deterministic => "peer-det",
                PeerMode::Fast => "peer-fast",
            };
            let label = format!("clu/relay w={workers} {peer_label}");
            let mut last = (0.0, 0, 0);
            bench(&label, 2, || {
                last = run_cluster(workers, peer, 1, false, nc);
                nc
            });
            let (_, data_frames, peer_frames) = last;
            println!("  {label}: coord_data_frames={data_frames} peer_frames={peer_frames}");
        }
    }

    // Pipelined injection rows: same relay workload with the source
    // batched 32 events per quiescence barrier, plus the peer-routed
    // Shuffle variant (fwd→sink g=shuffle, routed by the workers'
    // seeded rr cursors). Row names are additive — the PR-9 rows above
    // keep their names so the perf gate tracks both regimes.
    println!("\n(pipelined injection: inject window 32, deterministic peer links)");
    for (workers, shuffle) in [(2usize, false), (4, false), (2, true)] {
        let shape = if shuffle { "shuffle" } else { "relay" };
        let label = format!("clu/{shape} w={workers} peer-det inj32");
        let mut last = (0.0, 0, 0);
        bench(&label, 2, || {
            last = run_cluster(workers, PeerMode::Deterministic, 32, shuffle, nc);
            nc
        });
        let (_, data_frames, peer_frames) = last;
        println!("  {label}: coord_data_frames={data_frames} peer_frames={peer_frames}");
        assert!(
            data_frames <= nc.div_ceil(32),
            "{label}: expected ≤ {} batched coordinator frames, got {data_frames}",
            nc.div_ceil(32)
        );
    }
}
