//! Bench regenerating Table 4 (execution time on real-world datasets).

use samoa::common::cli::Args;

fn main() {
    let args = Args::parse(
        ["--instances", "40000", "--seeds", "1"].iter().map(|s| s.to_string()),
    );
    samoa::experiments::run("table4", &args).unwrap();
}
