//! Bench regenerating Table 4 (execution time on real-world datasets):
//! one timed row per (dataset twin × variant), instead of the old
//! single-shot `samoa exp table4` wrapper that produced no per-row
//! timings. Rows land in `BENCH_JSON` as `tput/table4 ...` records
//! (median seconds + instances/s), plus one `table4/quality ...` record
//! per row (accuracy/kappa/splits), so the CI perf-trajectory gate
//! tracks real-dataset throughput per PR.
//!
//! `BENCH_SMOKE` shrinks the workload (fewer instances, fewer variants)
//! for the CI smoke leg.

mod bench_util;
use bench_util::{bench, record_json, smoke_mode};

use samoa::experiments::dataset_stream;
use samoa::experiments::runner::{run_variant, EngineKind, Variant};

fn main() {
    let smoke = smoke_mode();
    let n: u64 = if smoke { 4_000 } else { 60_000 };
    // The paper's Table 4 feedback latency for the distributed variants.
    let kind = EngineKind::LocalDeterministic { feedback_delay: 100 };
    let datasets = ["elec", "phy", "covtype"];
    let variants: &[Variant] = if smoke {
        &[Variant::Moa, Variant::Local, Variant::Wok { p: 2 }]
    } else {
        &[
            Variant::Moa,
            Variant::Local,
            Variant::Wok { p: 2 },
            Variant::Wok { p: 4 },
            Variant::Wk { p: 2, z: 1 },
            Variant::Sharding { p: 2 },
        ]
    };

    for ds in datasets {
        for &variant in variants {
            // Accuracy is deterministic given (dataset seed, variant); run
            // it once outside the timed reps and attach it to the record.
            let mut acc_stream = dataset_stream(ds, 500);
            let out = run_variant(acc_stream.as_mut(), variant, n, kind, false, n);
            let name = format!("tput/table4 {ds} {variant}");
            bench(&name, 5, || {
                let mut stream = dataset_stream(ds, 500);
                run_variant(stream.as_mut(), variant, n, kind, false, n);
                n
            });
            record_json(
                &format!("table4/quality {ds} {variant}"),
                &[
                    ("accuracy", out.accuracy),
                    ("kappa", out.kappa),
                    ("splits", out.splits as f64),
                    ("model_bytes", out.model_bytes as f64),
                ],
            );
        }
    }
}
