//! Preprocess subsystem benches: sketch update throughput (CountMin /
//! Misra-Gries inserts per second), end-to-end `Pipeline` overhead
//! against a raw stream pass-through, the discretizer's Fenwick-backed
//! rank query against the naive O(fine) prefix scan, and the stats-sync
//! overhead of the prequential topology at p ∈ {1, 2, 4, 8}.

mod bench_util;
use bench_util::{bench, record_json, smoke_mode};

use std::time::Instant;

use samoa::common::zipf::Zipf;
use samoa::common::Rng;
use samoa::preprocess::{
    CountMinSketch, Discretizer, FeatureHasher, MisraGries, Pipeline, StandardScaler,
    TransformedStream,
};
use samoa::streams::random_tweet::RandomTweetGenerator;
use samoa::streams::waveform::WaveformGenerator;
use samoa::streams::StreamSource;

fn sketch_benches() {
    let n: usize = if smoke_mode() { 50_000 } else { 2_000_000 };
    let mut rng = Rng::new(1);
    let zipf = Zipf::new(10_000, 1.2);
    let items: Vec<u64> = (0..n).map(|_| zipf.sample(&mut rng) as u64).collect();

    for (w, d) in [(1024usize, 4usize), (4096, 6)] {
        let mut cm = CountMinSketch::new(w, d);
        bench(&format!("countmin {w}x{d} add"), 5, || {
            for &x in &items {
                cm.add(x, 1);
            }
            items.len() as u64
        });
    }

    for k in [64usize, 512] {
        let mut mg = MisraGries::new(k);
        bench(&format!("misra-gries k={k} add"), 5, || {
            for &x in &items {
                mg.add(x);
            }
            items.len() as u64
        });
    }
}

/// Drain `n` instances from a source, returning n (for items/s).
fn drain(src: &mut dyn StreamSource, n: u64) -> u64 {
    let mut count = 0;
    while count < n {
        let Some(i) = src.next_instance() else { break };
        std::hint::black_box(i.n_attributes());
        count += 1;
    }
    count
}

fn pipeline_benches() {
    let n: u64 = if smoke_mode() { 5_000 } else { 50_000 };

    bench("waveform raw pass-through", 5, || {
        let mut s = WaveformGenerator::classification(7);
        drain(&mut s, n)
    });

    bench("waveform | scale", 5, || {
        let mut s = TransformedStream::new(
            WaveformGenerator::classification(7),
            Pipeline::new().then(StandardScaler::new()),
        );
        drain(&mut s, n)
    });

    bench("waveform | scale,discretize:8", 5, || {
        let mut s = TransformedStream::new(
            WaveformGenerator::classification(7),
            Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8)),
        );
        drain(&mut s, n)
    });

    bench("tweets(d=1000) raw pass-through", 5, || {
        let mut s = RandomTweetGenerator::new(1000, 7);
        drain(&mut s, n)
    });

    bench("tweets(d=1000) | hash:64,scale", 5, || {
        let mut s = TransformedStream::new(
            RandomTweetGenerator::new(1000, 7),
            Pipeline::new().then(FeatureHasher::new(64)).then(StandardScaler::new()),
        );
        drain(&mut s, n)
    });
}

/// Fenwick rank query vs the naive O(fine) prefix scan on a
/// discretizer-heavy setup (large fine-cell count). Asserts the cached
/// path is not slower — the regression the prefix-sum rewrite fixes.
fn discretizer_rank_benches() {
    use samoa::core::Schema;

    let schema = Schema::classification("b", Schema::all_numeric(1), 2);
    let mut d = samoa::preprocess::Discretizer::with_resolution(8, 256, 2048);
    samoa::preprocess::Transform::bind(&mut d, &schema);
    let mut rng = Rng::new(5);
    let inserts = if smoke_mode() { 10_000 } else { 100_000 };
    for _ in 0..inserts {
        let x = (rng.gaussian() * 10.0) as f32;
        let _ = samoa::preprocess::Transform::transform(
            &mut d,
            samoa::core::Instance::dense(vec![x], samoa::core::instance::Label::None),
        );
    }
    let n_queries = if smoke_mode() { 20_000 } else { 200_000 };
    let queries: Vec<f64> = (0..n_queries).map(|_| rng.gaussian() * 12.0).collect();

    let time = |name: &str, f: &dyn Fn(f64) -> f64| -> f64 {
        let mut acc = 0.0;
        for &q in &queries {
            acc += f(q); // warmup + sanity
        }
        std::hint::black_box(acc);
        let t0 = Instant::now();
        let mut acc = 0.0;
        for &q in &queries {
            acc += f(q);
        }
        std::hint::black_box(acc);
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "{name:<48} {:>9.3}ms  ranks/s={:>12.0}",
            secs * 1e3,
            queries.len() as f64 / secs.max(1e-12)
        );
        secs
    };
    let cached = time("discretizer rank (fenwick, fine=2048)", &|q| d.rank(0, q));
    let naive = time("discretizer rank (naive scan, fine=2048)", &|q| d.rank_naive(0, q));
    println!(
        "rank speedup (naive/fenwick): {:.1}x over {} queries",
        naive / cached.max(1e-12),
        queries.len()
    );
    if !smoke_mode() {
        assert!(
            cached <= naive,
            "fenwick rank ({cached:.4}s) must not be slower than the naive scan ({naive:.4}s)"
        );
    }
}

/// Stats-sync overhead: the prequential classifier topology at
/// p ∈ {1, 2, 4, 8}, delta-sync off vs on (interval 256), local engine.
/// Also reports the sync message volume per configuration and asserts the
/// coalesced broadcast schedule: ONE `StatsGlobal` per stage per round of
/// `p` deltas, i.e. total broadcast deliveries == total deltas (the
/// pre-coalescing protocol paid `deltas × p`, O(p²) per round).
fn sync_benches() {
    use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use samoa::core::model::Classifier;
    use samoa::core::Schema;
    use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use samoa::preprocess::processor::{build_prequential_topology_head, LearnerHead};
    use samoa::topology::Event;
    use std::cell::Cell;
    use std::sync::Arc;

    use samoa::preprocess::SyncPolicy;

    let n: u64 = if smoke_mode() { 4_096 } else { 20_000 };
    for p in [1usize, 2, 4, 8] {
        for sync in [None, Some(SyncPolicy::Count(256))] {
            let label = match sync {
                Some(SyncPolicy::Count(i)) => format!("prequential topology p={p} sync={i}"),
                Some(policy) => format!("prequential topology p={p} sync={policy:?}"),
                None => format!("prequential topology p={p} sync=off"),
            };
            let msgs: Cell<(u64, u64)> = Cell::new((0, 0));
            bench(&label, 3, || {
                let mut stream = WaveformGenerator::classification(7);
                let schema = stream.schema().clone();
                let sink = EvalSink::new(schema.n_classes(), 1.0, n);
                let sink2 = Arc::clone(&sink);
                let (topo, handles) = build_prequential_topology_head(
                    &schema,
                    p,
                    sync,
                    |_| {
                        samoa::preprocess::Pipeline::new()
                            .then(samoa::preprocess::StandardScaler::new())
                            .then(samoa::preprocess::Discretizer::new(8))
                    },
                    LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn Classifier> {
                        Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
                    })),
                    move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
                );
                let source = (0..n).map_while(|id| {
                    stream.next_instance().map(|inst| Event::Instance { id, inst })
                });
                let m = samoa::engine::LocalEngine::new().run(&topo, handles.entry, source, |_| {});
                if let (Some(d), Some(g)) = (handles.delta, handles.global) {
                    msgs.set((m.streams[d.0].events, m.streams[g.0].events));
                }
                m.source_instances
            });
            if sync.is_some() {
                let (deltas, globals) = msgs.get();
                println!(
                    "  sync messages p={p}: deltas={deltas} global deliveries={globals} \
                     (coalesced: 1 broadcast per stage per round of {p} deltas; \
                     pre-coalescing would deliver {})",
                    deltas * p as u64
                );
                record_json(
                    &format!("sync messages p={p}"),
                    &[("deltas", deltas as f64), ("global_deliveries", globals as f64)],
                );
                assert_eq!(
                    globals, deltas,
                    "coalescing regressed: global deliveries must equal deltas \
                     (one broadcast × p destinations per round of p deltas; \
                     per-shard rounds keep this exact under the local engine's \
                     lockstep schedule)"
                );
            }
        }
    }
}

/// The policy × compression sweep: drift-gated / hybrid / count emission
/// crossed with sparse-vs-dense delta encoding, on a sparse
/// bag-of-words stream (tweets d=1000, top-k filter + scaler) where
/// compression has room to work. Reports sync message counts and wire
/// bytes per configuration and asserts compression shrinks the
/// count-policy delta stream (identical emission schedule, smaller
/// payloads).
fn sync_policy_compression_benches() {
    use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
    use samoa::core::model::Classifier;
    use samoa::core::Schema;
    use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
    use samoa::preprocess::processor::{
        build_prequential_topology_sync, LearnerHead, SyncPolicy,
    };
    use samoa::preprocess::TopKFilter;
    use samoa::topology::Event;
    use std::sync::Arc;

    let n: u64 = if smoke_mode() { 4_096 } else { 20_000 };
    let p = 4usize;
    let policies = [
        ("count:256", SyncPolicy::Count(256)),
        ("drift:512", SyncPolicy::Drift { delta: 0.002, max_staleness: 512 }),
        ("hybrid:256", SyncPolicy::Hybrid { interval: 256, delta: 0.002 }),
    ];
    println!("-- sync policy × compression sweep (tweets d=1000 | topk:32,scale, p={p}) --");
    let mut count_delta_bytes = [0u64; 2]; // [dense, sparse] for the count row
    for (pname, policy) in policies {
        for compress in [false, true] {
            let label = format!(
                "sync sweep {pname} {}",
                if compress { "sparse" } else { "dense " }
            );
            let mut delta_stats = (0u64, 0u64, 0u64, 0u64); // events, bytes × delta/global
            bench(&label, 3, || {
                let mut stream = RandomTweetGenerator::new(1000, 7);
                let schema = stream.schema().clone();
                let sink = EvalSink::new(schema.n_classes(), 1.0, n);
                let sink2 = Arc::clone(&sink);
                let (topo, handles) = build_prequential_topology_sync(
                    &schema,
                    p,
                    Some(policy),
                    compress,
                    |_| {
                        Pipeline::new()
                            .then(TopKFilter::new(32))
                            .then(StandardScaler::new())
                    },
                    LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn Classifier> {
                        Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
                    })),
                    move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
                );
                let source = (0..n).map_while(|id| {
                    stream.next_instance().map(|inst| Event::Instance { id, inst })
                });
                let m = samoa::engine::LocalEngine::new().run(&topo, handles.entry, source, |_| {});
                let (d, g) = (handles.delta.unwrap(), handles.global.unwrap());
                delta_stats = (
                    m.streams[d.0].events,
                    m.streams[d.0].bytes,
                    m.streams[g.0].events,
                    m.streams[g.0].bytes,
                );
                m.source_instances
            });
            let (de, db, ge, gb) = delta_stats;
            println!(
                "  {pname} {}: deltas={de} ({db}B) globals={ge} ({gb}B) total sync bytes={}",
                if compress { "sparse" } else { "dense" },
                db + gb
            );
            record_json(
                &format!("sync sweep {pname} {}", if compress { "sparse" } else { "dense" }),
                &[
                    ("deltas", de as f64),
                    ("delta_bytes", db as f64),
                    ("global_deliveries", ge as f64),
                    ("global_bytes", gb as f64),
                ],
            );
            if pname == "count:256" {
                count_delta_bytes[compress as usize] = db;
            }
        }
    }
    assert!(
        count_delta_bytes[1] < count_delta_bytes[0],
        "sparse deltas must beat dense on a sparse stream: {} !< {}",
        count_delta_bytes[1],
        count_delta_bytes[0]
    );
}

fn main() {
    println!("== preprocess benches ==");
    sketch_benches();
    pipeline_benches();
    discretizer_rank_benches();
    sync_benches();
    sync_policy_compression_benches();
}
