//! Preprocess subsystem benches: sketch update throughput (CountMin /
//! Misra-Gries inserts per second) and end-to-end `Pipeline` overhead
//! against a raw stream pass-through.

mod bench_util;
use bench_util::bench;

use samoa::common::zipf::Zipf;
use samoa::common::Rng;
use samoa::preprocess::{
    CountMinSketch, Discretizer, FeatureHasher, MisraGries, Pipeline, StandardScaler,
    TransformedStream,
};
use samoa::streams::random_tweet::RandomTweetGenerator;
use samoa::streams::waveform::WaveformGenerator;
use samoa::streams::StreamSource;

fn sketch_benches() {
    const N: usize = 2_000_000;
    let mut rng = Rng::new(1);
    let zipf = Zipf::new(10_000, 1.2);
    let items: Vec<u64> = (0..N).map(|_| zipf.sample(&mut rng) as u64).collect();

    for (w, d) in [(1024usize, 4usize), (4096, 6)] {
        let mut cm = CountMinSketch::new(w, d);
        bench(&format!("countmin {w}x{d} add"), 5, || {
            for &x in &items {
                cm.add(x, 1);
            }
            items.len() as u64
        });
    }

    for k in [64usize, 512] {
        let mut mg = MisraGries::new(k);
        bench(&format!("misra-gries k={k} add"), 5, || {
            for &x in &items {
                mg.add(x);
            }
            items.len() as u64
        });
    }
}

/// Drain `n` instances from a source, returning n (for items/s).
fn drain(src: &mut dyn StreamSource, n: u64) -> u64 {
    let mut count = 0;
    while count < n {
        let Some(i) = src.next_instance() else { break };
        std::hint::black_box(i.n_attributes());
        count += 1;
    }
    count
}

fn pipeline_benches() {
    const N: u64 = 50_000;

    bench("waveform raw pass-through", 5, || {
        let mut s = WaveformGenerator::classification(7);
        drain(&mut s, N)
    });

    bench("waveform | scale", 5, || {
        let mut s = TransformedStream::new(
            WaveformGenerator::classification(7),
            Pipeline::new().then(StandardScaler::new()),
        );
        drain(&mut s, N)
    });

    bench("waveform | scale,discretize:8", 5, || {
        let mut s = TransformedStream::new(
            WaveformGenerator::classification(7),
            Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8)),
        );
        drain(&mut s, N)
    });

    bench("tweets(d=1000) raw pass-through", 5, || {
        let mut s = RandomTweetGenerator::new(1000, 7);
        drain(&mut s, N)
    });

    bench("tweets(d=1000) | hash:64,scale", 5, || {
        let mut s = TransformedStream::new(
            RandomTweetGenerator::new(1000, 7),
            Pipeline::new().then(FeatureHasher::new(64)).then(StandardScaler::new()),
        );
        drain(&mut s, N)
    });
}

fn main() {
    println!("== preprocess benches ==");
    sketch_benches();
    pipeline_benches();
}
