//! Engine micro-benchmarks (§Perf L3): raw event routing throughput of
//! the local and threaded engines, with and without attribute batching —
//! the hot path under every experiment.

mod bench_util;
use bench_util::bench;

use samoa::core::instance::{Instance, Label};
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::topology::{Ctx, Event, Grouping, Processor, TopologyBuilder};

struct Nop;
impl Processor for Nop {
    fn process(&mut self, _e: Event, _c: &mut Ctx) {}
}

/// MA-like fan-out: decompose each instance into A attribute events.
struct FanOut {
    attrs: usize,
    out: samoa::topology::StreamId,
}
impl Processor for FanOut {
    fn process(&mut self, e: Event, ctx: &mut Ctx) {
        if let Event::Instance { id, .. } = e {
            for a in 0..self.attrs {
                ctx.emit(
                    self.out,
                    samoa::topology::stream::leaf_attr_key(id, a as u32),
                    Event::Attribute { leaf: 0, attr: a as u32, value: 1.0, class: 0, weight: 1.0 },
                );
            }
        }
    }
}

fn inst(id: u64) -> Event {
    Event::Instance { id, inst: Instance::dense(vec![0.0; 16], Label::Class(0)) }
}

fn main() {
    let n = 50_000u64;

    bench("local engine: 1-stage pass-through", 10, || {
        let mut b = TopologyBuilder::new("t");
        let p = b.add_processor("w", 1, |_| Box::new(Nop));
        let entry = b.stream("in", None, p, Grouping::Shuffle);
        let topo = b.build();
        LocalEngine::new().run(&topo, entry, (0..n).map(inst), |_| {});
        n
    });

    for attrs in [16usize, 64] {
        bench(&format!("local engine: fan-out x{attrs} key-grouped"), 5, || {
            let mut b = TopologyBuilder::new("t");
            let ls = samoa::topology::StreamId(1);
            let ma = b.add_processor("ma", 1, move |_| Box::new(FanOut { attrs, out: ls }));
            let l = b.add_processor("ls", 4, |_| Box::new(Nop));
            let entry = b.stream("in", None, ma, Grouping::Shuffle);
            b.stream("attr", Some(ma), l, Grouping::Key);
            let topo = b.build();
            let m = LocalEngine::new().run(&topo, entry, (0..n / 10).map(inst), |_| {});
            m.streams[1].events
        });
    }

    bench("threaded engine: 4-way shuffle", 5, || {
        let mut b = TopologyBuilder::new("t");
        let p = b.add_processor("w", 4, |_| Box::new(Nop));
        let entry = b.stream("in", None, p, Grouping::Shuffle);
        let topo = b.build();
        ThreadedEngine::default().run(&topo, entry, (0..n).map(inst), |_, _, _| {});
        n
    });

    bench("threaded engine: tiny queues (backpressure)", 5, || {
        let mut b = TopologyBuilder::new("t");
        let p = b.add_processor("w", 2, |_| Box::new(Nop));
        let entry = b.stream("in", None, p, Grouping::Shuffle);
        let topo = b.build();
        ThreadedEngine::new(8).run(&topo, entry, (0..n / 5).map(inst), |_, _, _| {});
        n / 5
    });
}
