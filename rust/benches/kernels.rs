//! Kernel micro-benchmarks: native rust vs AOT XLA artifact for the three
//! criterion kernels (info gain, SDR, cluster assignment) — the §Perf L1
//! evidence and the native/XLA crossover measurement.

mod bench_util;
use bench_util::bench;

use samoa::common::Rng;
use samoa::core::criterion::VarStats;
use samoa::core::observers::CounterBlock;
use samoa::runtime::{cluster, gain, registry, sdr};

fn blocks(n: usize, seed: u64) -> Vec<CounterBlock> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut b = CounterBlock::new(16, 8);
            for _ in 0..200 {
                b.add(rng.below(16) as u32, rng.below(8) as u32, 1.0);
            }
            b
        })
        .collect()
}

fn main() {
    println!(
        "== kernel benches (backend availability: {:?}) ==",
        registry::artifacts_dir().is_some()
    );

    for n in [64usize, 256, 1024] {
        let bs = blocks(n, 1);
        let refs: Vec<&CounterBlock> = bs.iter().collect();
        bench(&format!("infogain native   A={n}"), 20, || {
            std::hint::black_box(gain::gains_native(&refs));
            n as u64
        });
        if registry::artifacts_dir().is_some() {
            bench(&format!("infogain xla      A={n}"), 20, || {
                std::hint::black_box(gain::gains_xla(&refs).unwrap());
                n as u64
            });
        }
    }

    let mut rng = Rng::new(2);
    let attrs: Vec<Vec<VarStats>> = (0..64)
        .map(|_| {
            (0..64)
                .map(|_| {
                    let mut s = VarStats::default();
                    for _ in 0..10 {
                        s.add(rng.gaussian(), 1.0);
                    }
                    s
                })
                .collect()
        })
        .collect();
    bench("sdr native        A=64 B=64", 20, || {
        std::hint::black_box(sdr::sdr_native(&attrs));
        64
    });
    if registry::artifacts_dir().is_some() {
        bench("sdr xla           A=64 B=64", 20, || {
            std::hint::black_box(sdr::sdr_xla(&attrs).unwrap());
            64
        });
    }

    let (n, k, d) = (128usize, 128usize, 64usize);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
    let ctr: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
    let w = vec![1f32; k];
    bench("cluster native    N=128 K=128 D=64", 20, || {
        std::hint::black_box(cluster::assign_native(&pts, &ctr, &w, d));
        n as u64
    });
    if registry::artifacts_dir().is_some() {
        bench("cluster xla       N=128 K=128 D=64", 20, || {
            std::hint::black_box(cluster::assign_xla(&pts, &ctr, &w, d).unwrap());
            n as u64
        });
    }
}
