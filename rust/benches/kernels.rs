//! Kernel micro-benchmarks: native rust vs lane-unrolled SIMD (vs AOT
//! XLA artifact where available) for the three criterion kernels — info
//! gain, SDR, cluster assignment. The §Perf L1 evidence and the backend
//! crossover measurement; rows are named `kern/…` so `BENCH_JSON` runs
//! feed the CI perf-trajectory gate.
//!
//! The summary at the end prints the SIMD speedup per kernel. Info gain
//! at the default 16×8 block shape carries a ≥ 1.5× target (PASS/WARN,
//! report-only): that is the shape VHT actually evaluates, and the fused
//! `Σ x·log2 x` lane pass is where the SIMD backend earns its probe win.

mod bench_util;
use bench_util::bench;

use samoa::common::Rng;
use samoa::core::criterion::VarStats;
use samoa::core::observers::CounterBlock;
use samoa::runtime::{cluster, gain, registry, sdr, xla};

fn blocks(n: usize, seed: u64) -> Vec<CounterBlock> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut b = CounterBlock::new(16, 8);
            for _ in 0..200 {
                b.add(rng.below(16) as u32, rng.below(8) as u32, 1.0);
            }
            b
        })
        .collect()
}

fn main() {
    let xla_ready = registry::artifacts_dir().is_some() && xla::AVAILABLE;
    println!("== kernel benches (xla artifacts usable: {xla_ready:?}) ==");

    // (label, native items/s, simd items/s), for the speedup summary
    let mut pairs: Vec<(String, f64, f64)> = Vec::new();

    let mut infogain_speedup_a256 = 0.0f64;
    for n in [64usize, 256, 1024] {
        let bs = blocks(n, 1);
        let refs: Vec<&CounterBlock> = bs.iter().collect();
        let nat = bench(&format!("kern/infogain_native_a{n}"), 20, || {
            std::hint::black_box(gain::gains_native(&refs));
            n as u64
        });
        let sim = bench(&format!("kern/infogain_simd_a{n}"), 20, || {
            std::hint::black_box(gain::gains_simd(&refs));
            n as u64
        });
        pairs.push((format!("infogain 16x8 A={n}"), nat, sim));
        if n == 256 {
            infogain_speedup_a256 = sim / nat.max(1e-12);
        }
        if xla_ready {
            bench(&format!("kern/infogain_xla_a{n}"), 20, || {
                std::hint::black_box(gain::gains_xla(&refs).unwrap());
                n as u64
            });
        }
    }

    let mut rng = Rng::new(2);
    let attrs: Vec<Vec<VarStats>> = (0..64)
        .map(|_| {
            (0..64)
                .map(|_| {
                    let mut s = VarStats::default();
                    for _ in 0..10 {
                        s.add(rng.gaussian(), 1.0);
                    }
                    s
                })
                .collect()
        })
        .collect();
    let nat = bench("kern/sdr_native_a64_b64", 20, || {
        std::hint::black_box(sdr::sdr_native(&attrs));
        64
    });
    let sim = bench("kern/sdr_simd_a64_b64", 20, || {
        std::hint::black_box(sdr::sdr_simd(&attrs));
        64
    });
    pairs.push(("sdr A=64 B=64".to_string(), nat, sim));
    if xla_ready {
        bench("kern/sdr_xla_a64_b64", 20, || {
            std::hint::black_box(sdr::sdr_xla(&attrs).unwrap());
            64
        });
    }

    let (n, k, d) = (128usize, 128usize, 64usize);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
    let ctr: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
    let w = vec![1f32; k];
    let nat = bench("kern/cluster_native_n128_k128_d64", 20, || {
        std::hint::black_box(cluster::assign_native(&pts, &ctr, &w, d));
        n as u64
    });
    let sim = bench("kern/cluster_simd_n128_k128_d64", 20, || {
        std::hint::black_box(cluster::assign_simd(&pts, &ctr, &w, d));
        n as u64
    });
    pairs.push(("cluster N=128 K=128 D=64".to_string(), nat, sim));
    if xla_ready {
        bench("kern/cluster_xla_n128_k128_d64", 20, || {
            std::hint::black_box(cluster::assign_xla(&pts, &ctr, &w, d).unwrap());
            n as u64
        });
    }

    println!("\n== simd vs native speedup ==");
    for (label, nat, sim) in &pairs {
        println!("{label:<28} simd/native = {:>5.2}x", sim / nat.max(1e-12));
    }
    let verdict = if infogain_speedup_a256 >= 1.5 { "PASS" } else { "WARN" };
    println!(
        "info-gain 16x8 A=256 speedup {:.2}x (target: >= 1.50x) -> {verdict}",
        infogain_speedup_a256
    );
    println!("probe decision for this machine: {:?}", registry::backend_in_use());
}
