//! Bench regenerating Figs 12-13 (AMRules throughput / message-size cap).

use samoa::common::cli::Args;

fn main() {
    let args = Args::parse(["--instances", "10000"].iter().map(|s| s.to_string()));
    samoa::experiments::run("fig12", &args).unwrap();
    samoa::experiments::run("fig13", &args).unwrap();
}
