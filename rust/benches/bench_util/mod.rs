//! Tiny bench harness (criterion is not available offline): warmup +
//! repeated timed runs, median/min/max reporting.
//!
//! Setting the `BENCH_SMOKE` env var puts the harness in CI smoke mode:
//! benches shrink their workloads via [`smoke_mode`], and every bench
//! runs one warmup + three timed reps with the *median* reported — the
//! numbers feed the perf-trajectory gate, and a single cold rep of a
//! sub-millisecond run on a shared runner is noise, not a measurement.

use std::io::Write;
use std::time::Instant;

/// True when the `BENCH_SMOKE` env var is set (CI smoke mode).
pub fn smoke_mode() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// When the `BENCH_JSON` env var names a file, append one JSON object
/// (one line) with the given numeric fields — the machine-readable twin
/// of the printed bench rows. CI collects the lines into
/// `BENCH_PR<k>.json` and uploads them as a workflow artifact, so the
/// perf trajectory (events/sec, sync bytes, broadcast counts, …) is
/// tracked per PR instead of lost in logs. Non-finite values serialize
/// as `null`.
#[allow(dead_code)]
pub fn record_json(name: &str, fields: &[(&str, f64)]) {
    let Some(path) = std::env::var_os("BENCH_JSON") else { return };
    let mut line = String::from("{\"name\":\"");
    for c in name.chars() {
        match c {
            '"' => line.push_str("\\\""),
            '\\' => line.push_str("\\\\"),
            c if (c as u32) < 0x20 => line.push(' '),
            c => line.push(c),
        }
    }
    line.push('"');
    for (key, value) in fields {
        line.push_str(",\"");
        line.push_str(key);
        line.push_str("\":");
        if value.is_finite() {
            line.push_str(&format!("{value}"));
        } else {
            line.push_str("null");
        }
    }
    line.push('}');
    let file = std::fs::OpenOptions::new().create(true).append(true).open(path);
    if let Ok(mut f) = file {
        let _ = writeln!(f, "{line}");
    }
}

/// Time `f` `reps` times after one warmup; print a stats row. In smoke
/// mode exactly three reps run (median reported — shrunk workloads are
/// fast enough that one rep is runner-jitter, which would flap the CI
/// perf gate).
///
/// Returns the median-based throughput (items/s) so benches comparing
/// two implementations of the same job (e.g. native vs SIMD kernels)
/// can print speedup ratios; most callers ignore it.
pub fn bench<F: FnMut() -> u64>(name: &str, reps: usize, mut f: F) -> f64 {
    let reps = if smoke_mode() { 3 } else { reps };
    let _ = f(); // warmup
    let mut times = Vec::with_capacity(reps);
    let mut items = 0u64;
    for _ in 0..reps {
        let t0 = Instant::now();
        items = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let max = times[times.len() - 1];
    let tput = items as f64 / median.max(1e-12);
    println!(
        "{name:<48} median={:>9.3}ms  min={:>9.3}ms  max={:>9.3}ms  items/s={tput:>12.0}",
        median * 1e3,
        min * 1e3,
        max * 1e3,
    );
    record_json(
        name,
        &[
            ("median_s", median),
            ("min_s", min),
            ("max_s", max),
            ("items_per_s", tput),
        ],
    );
    tput
}
