//! Tiny bench harness (criterion is not available offline): warmup +
//! repeated timed runs, median/min/max reporting.
//!
//! Setting the `BENCH_SMOKE` env var puts the harness in CI smoke mode:
//! a single timed rep per bench (and benches may shrink their workloads
//! via [`smoke_mode`]) — the goal there is "the perf code still builds
//! and runs", not stable numbers.

use std::time::Instant;

/// True when the `BENCH_SMOKE` env var is set (CI smoke mode).
pub fn smoke_mode() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// Time `f` `reps` times after one warmup; print a stats row. In smoke
/// mode the warmup is skipped and exactly one rep runs.
pub fn bench<F: FnMut() -> u64>(name: &str, reps: usize, mut f: F) {
    let reps = if smoke_mode() { 1 } else { reps };
    if !smoke_mode() {
        let _ = f(); // warmup
    }
    let mut times = Vec::with_capacity(reps);
    let mut items = 0u64;
    for _ in 0..reps {
        let t0 = Instant::now();
        items = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let max = times[times.len() - 1];
    let tput = items as f64 / median.max(1e-12);
    println!(
        "{name:<48} median={:>9.3}ms  min={:>9.3}ms  max={:>9.3}ms  items/s={tput:>12.0}",
        median * 1e3,
        min * 1e3,
        max * 1e3,
    );
}
