//! Bench for Fig 3's time panel: execution time of the sequential tree
//! vs VHT-local across dense/sparse configurations.

mod bench_util;
use bench_util::bench;

use samoa::experiments::runner::{run_variant, EngineKind, Variant};
use samoa::streams::random_tree::RandomTreeGenerator;
use samoa::streams::random_tweet::RandomTweetGenerator;

fn main() {
    let n = 30_000u64;
    for (cat, num) in [(10, 10), (100, 100)] {
        for v in [Variant::Moa, Variant::Local] {
            bench(&format!("fig3 dense {cat}-{num} {v}"), 5, || {
                let mut s = RandomTreeGenerator::new(cat, num, 2, 42);
                let kind = EngineKind::LocalDeterministic { feedback_delay: 0 };
                run_variant(&mut s, v, n, kind, false, n);
                n
            });
        }
    }
    for dim in [100u32, 1000] {
        for v in [Variant::Moa, Variant::Local] {
            bench(&format!("fig3 sparse {dim} {v}"), 5, || {
                let mut s = RandomTweetGenerator::new(dim, 42);
                let kind = EngineKind::LocalDeterministic { feedback_delay: 0 };
                run_variant(&mut s, v, n, kind, true, n);
                n
            });
        }
    }
}
