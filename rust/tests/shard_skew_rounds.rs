//! Per-shard sync-round exactness under shard skew (the data-plane
//! follow-up): one pipeline shard is artificially slow and one is
//! drift-gated silent, and the aggregator must still never merge two
//! deltas from the same shard into one broadcast round — fast shards
//! lapping a round close it early (skew round) instead of padding it.
//!
//! The local-engine leg pins the exact deterministic schedule; the
//! threaded leg pins the invariants under real thread interleaving.

use std::time::{Duration, Instant};

use samoa::core::{Instance, Schema};
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::preprocess::processor::PipelineProcessor;
use samoa::preprocess::{Pipeline, StandardScaler, StatsSyncProcessor, SyncPolicy, Transform};
use samoa::streams::waveform::WaveformGenerator;
use samoa::streams::StreamSource;
use samoa::topology::{Ctx, Event, Grouping, Processor, StreamId, TopologyBuilder};

const N: u64 = 4096;
const P: usize = 4;
const INTERVAL: u64 = 32;

/// Transform wrapper that burns wall-clock per instance (threaded-skew
/// injection) while delegating state/sync hooks to the inner operator —
/// the stage layout stays identical across shards, so stage ids and
/// payload shapes line up at the aggregator.
struct Slow<T: Transform> {
    inner: T,
    spin: Duration,
}

impl<T: Transform> Transform for Slow<T> {
    fn bind(&mut self, input: &Schema) -> Schema {
        self.inner.bind(input)
    }

    fn transform(&mut self, inst: Instance) -> Option<Instance> {
        if !self.spin.is_zero() {
            let t0 = Instant::now();
            while t0.elapsed() < self.spin {
                std::hint::spin_loop();
            }
        }
        self.inner.transform(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        self.inner.stats_delta()
    }

    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        self.inner.stats_delta_dense()
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        self.inner.stats_merge(payload)
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        self.inner.stats_snapshot()
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        self.inner.stats_apply(payload)
    }

    fn track_drift_signal(&mut self, on: bool) {
        self.inner.track_drift_signal(on)
    }

    fn drift_signal(&mut self) -> Option<f64> {
        self.inner.drift_signal()
    }

    fn name(&self) -> &'static str {
        "slow"
    }
}

/// Delegating wrapper that reports no drift signal: under
/// `SyncPolicy::Drift` the stage's gate is never fed, so with an
/// unreachable backstop the shard is *deterministically* silent until
/// its shutdown flush — the "drift-gated shard that legitimately skips
/// rounds" of the round-exactness contract.
struct Mute<T: Transform> {
    inner: T,
}

impl<T: Transform> Transform for Mute<T> {
    fn bind(&mut self, input: &Schema) -> Schema {
        self.inner.bind(input)
    }

    fn transform(&mut self, inst: Instance) -> Option<Instance> {
        self.inner.transform(inst)
    }

    fn stats_delta(&mut self) -> Option<Vec<f64>> {
        self.inner.stats_delta()
    }

    fn stats_delta_dense(&mut self) -> Option<Vec<f64>> {
        self.inner.stats_delta_dense()
    }

    fn stats_merge(&mut self, payload: &[f64]) {
        self.inner.stats_merge(payload)
    }

    fn stats_snapshot(&self) -> Option<Vec<f64>> {
        self.inner.stats_snapshot()
    }

    fn stats_apply(&mut self, payload: &[f64]) {
        self.inner.stats_apply(payload)
    }

    // tracking intentionally NOT forwarded and the signal pinned to
    // None: the gate of this shard is never fed
    fn drift_signal(&mut self) -> Option<f64> {
        None
    }

    fn name(&self) -> &'static str {
        "mute"
    }
}

/// Counts whatever reaches it (the learner stand-in).
struct Sink;

impl Processor for Sink {
    fn process(&mut self, _event: Event, _ctx: &mut Ctx) {}
}

/// Aggregator counters extracted after a run.
#[derive(Clone, Debug, Default)]
struct AggStats {
    deltas_merged: u64,
    broadcasts: u64,
    completed_rounds: u64,
    skew_rounds: u64,
    /// (contributors, merged, skew_closed) per closed round.
    audit: Vec<(u32, u32, bool)>,
    /// Master scaler observation count on attribute 0.
    master_n: f64,
}

fn extract(agg: &StatsSyncProcessor) -> AggStats {
    AggStats {
        deltas_merged: agg.deltas_merged(),
        broadcasts: agg.broadcasts(),
        completed_rounds: agg.completed_rounds(),
        skew_rounds: agg.skew_rounds(),
        audit: agg
            .round_audit()
            .iter()
            .map(|r| (r.contributors, r.merged, r.skew_closed))
            .collect(),
        master_n: agg.snapshot(0).map_or(0.0, |s| s[0]),
    }
}

/// Build the skewed sync topology: `source → pipeline×4 → sink`, with
/// the delta/global loop to a `StatsSyncProcessor`. Shard 0 burns
/// `slow_spin` per instance; shard 3 is drift-gated with an
/// unreachable backstop (silent until shutdown); shards 1/2 run
/// `Count(INTERVAL)`.
fn build(slow_spin: Duration) -> (samoa::topology::Topology, StreamId) {
    let schema = WaveformGenerator::classification(1).schema().clone();
    let out = StreamId(1);
    let delta = StreamId(2);
    let global = StreamId(3);

    let mut b = TopologyBuilder::new("skew");
    let s = schema.clone();
    let pipe = b.add_processor("pipeline", P, move |i| {
        let pipeline = match i {
            0 => Pipeline::new().then(Slow { inner: StandardScaler::new(), spin: slow_spin }),
            3 => Pipeline::new().then(Mute { inner: StandardScaler::new() }),
            _ => Pipeline::new().then(StandardScaler::new()),
        };
        let policy = if i == 3 {
            // drift-gated silent: the Mute stage feeds the gate nothing
            // and the backstop is unreachable — only the shutdown flush
            // emits
            SyncPolicy::Drift { delta: 0.002, max_staleness: u64::MAX }
        } else {
            SyncPolicy::Count(INTERVAL)
        };
        Box::new(PipelineProcessor::new(pipeline, &s, out).with_sync(policy, delta))
    });
    let sink = b.add_processor("sink", 1, |_| Box::new(Sink));
    let s2 = schema.clone();
    let stats = b.add_processor("stats-sync", 1, move |_| {
        Box::new(StatsSyncProcessor::new(
            Pipeline::new().then(StandardScaler::new()),
            &s2,
            global,
            P,
        ))
    });

    let entry = b.stream("instance", None, pipe, Grouping::Shuffle);
    let s_out = b.stream("transformed", Some(pipe), sink, Grouping::Shuffle);
    let s_delta = b.stream("stats-delta", Some(pipe), stats, Grouping::Key);
    let s_global = b.stream("stats-global", Some(stats), pipe, Grouping::All);
    assert_eq!(s_out, out);
    assert_eq!(s_delta, delta);
    assert_eq!(s_global, global);
    (b.build(), entry)
}

fn source_events() -> impl Iterator<Item = Event> {
    let mut stream = WaveformGenerator::classification(1);
    (0..N).map_while(move |id| stream.next_instance().map(|inst| Event::Instance { id, inst }))
}

/// Deterministic leg: the local engine's lockstep schedule makes the
/// skew accounting exact — shard 3 contributes nothing until its
/// shutdown flush, so every mid-run round is closed by a lapping shard
/// with exactly the three active members, and the flush completes the
/// final round with all four.
#[test]
fn local_engine_round_accounting_is_exact_with_silent_shard() {
    let (topo, entry) = build(Duration::ZERO);
    let mut stats = AggStats::default();
    LocalEngine::new().run(&topo, entry, source_events(), |instances| {
        if let Some(agg) = instances[2][0]
            .as_any()
            .and_then(|a| a.downcast_ref::<StatsSyncProcessor>())
        {
            stats = extract(agg);
        }
    });
    // 32 emission waves from each of shards 0/1/2 + shard 3's single
    // shutdown flush
    let waves = (N / P as u64) / INTERVAL; // 32
    assert_eq!(stats.deltas_merged, waves * 3 + 1, "{stats:?}");
    // waves 2..=32 each lap the previous 3-member round; shard 3's
    // shutdown flush completes the last round with all four members
    assert_eq!(stats.skew_rounds, waves - 1, "{stats:?}");
    assert_eq!(stats.completed_rounds, 1, "{stats:?}");
    assert_eq!(stats.broadcasts, waves, "{stats:?}");
    for &(contributors, merged, _) in &stats.audit {
        assert_eq!(contributors, merged, "a shard was merged twice into one round: {stats:?}");
    }
    // exactness: every observation reached the master exactly once
    assert_eq!(stats.master_n, N as f64, "{stats:?}");
}

/// Threaded leg: a genuinely slow shard 0 plus the silent shard 3 under
/// real interleaving. The exact round composition is nondeterministic
/// (arrival order varies); the accounting is not: every emitted delta is
/// merged exactly once, no round ever merges one shard twice — and,
/// with the engine's staged shutdown (per-processor Shutdown +
/// quiescence before the next stage), shard 3's shutdown-flush delta
/// *deterministically* reaches the aggregator before the aggregator's
/// own `on_shutdown`, so the old best-effort tolerance carve-out
/// ("shard 3's flush may or may not land") is gone: the totals are
/// exact on the threaded engine too.
#[test]
fn threaded_skew_never_merges_a_shard_twice_per_round() {
    let (topo, entry) = build(Duration::from_micros(60));
    let mut stats = AggStats::default();
    ThreadedEngine::default().run(&topo, entry, source_events(), |pid, _iid, proc_| {
        if pid == 2 {
            if let Some(agg) = proc_.as_any().and_then(|a| a.downcast_ref::<StatsSyncProcessor>())
            {
                stats = extract(agg);
            }
        }
    });
    let waves = (N / P as u64) / INTERVAL; // 32 per active shard
    // exact: 32 mid-run deltas from each of shards 0/1/2 (control-plane
    // events all drain before shutdown) + shard 3's single staged
    // shutdown flush
    assert_eq!(stats.deltas_merged, waves * 3 + 1, "{stats:?}");
    assert!(stats.skew_rounds > 0, "slow shard produced no skew rounds: {stats:?}");
    // shard 3 is silent until shutdown, so at most the final flush can
    // complete a full 4-member round
    assert!(stats.completed_rounds <= 1, "{stats:?}");
    for &(contributors, merged, _) in &stats.audit {
        assert!(contributors >= 1 && contributors <= P as u32, "{stats:?}");
        assert_eq!(contributors, merged, "a shard was merged twice into one round: {stats:?}");
    }
    // exact master accounting: all four shards' observations — including
    // the silent shard's shutdown flush — reach the master exactly once
    assert_eq!(stats.master_n, N as f64, "{stats:?}");
}
