//! Recovery equivalence: a killed-and-recovered run must be
//! indistinguishable from an undisturbed one whenever the replay log
//! covered the whole post-checkpoint delta (`replay_dropped == 0`) —
//! the headline property of the checkpoint/restore layer, pinned here
//! on both engines that implement it:
//!
//! * [`ThreadedEngine`] `with_fault`: kill one instance mid-stream at
//!   and off checkpoint boundaries, demand byte-identical final
//!   snapshots and exact delivery totals, in both pinned and stealing
//!   modes; a deliberately tiny replay cap shows the documented loss
//!   (`replay_dropped > 0`, totals short by exactly the dropped
//!   events).
//! * [`ClusterEngine`] worker death: an injected worker panic
//!   (`die=`/`victim=` spec params) mid-run, coordinator respawn from
//!   held checkpoints plus replay-log re-drive, every delivery
//!   accounted for.
//! * Rescale: two shard checkpoints merged via `merge_shard_frames`
//!   and re-seeded into a wider topology through `with_restore`.

use samoa::common::Rng;
use samoa::core::instance::{Instance, Label};
use samoa::core::Schema;
use samoa::engine::checkpoint::{
    decode_frame, encode_frame, merge_shard_frames, section, TAG_META_BASE,
};
use samoa::engine::cluster::{spec, ClusterEngine};
use samoa::engine::ThreadedEngine;
use samoa::preprocess::{Pipeline, StandardScaler, Transform};
use samoa::topology::{Ctx, Event, Grouping, Processor, StreamId, Topology, TopologyBuilder};

const DIM: usize = 3;

fn schema() -> Schema {
    Schema::classification("t", Schema::all_numeric(DIM), 2)
}

/// A shard processor with genuinely bit-sensitive f64 state: a running
/// StandardScaler over everything it sees. Emits nothing, so runs are
/// deterministic on the threaded engine and final snapshots can be
/// compared byte-for-byte between a killed and an undisturbed run.
struct StatShard {
    scaler: StandardScaler,
    seen: u64,
}

impl StatShard {
    fn boxed() -> Box<dyn Processor> {
        let mut scaler = StandardScaler::new();
        scaler.bind(&schema());
        Box::new(StatShard { scaler, seen: 0 })
    }
}

impl Processor for StatShard {
    fn process(&mut self, event: Event, _ctx: &mut Ctx) {
        if let Event::Instance { inst, .. } = event {
            self.seen += 1;
            let _ = self.scaler.transform(inst);
        }
    }

    fn name(&self) -> &'static str {
        "stat-shard"
    }

    fn report(&self) -> Vec<(&'static str, f64)> {
        vec![("seen", self.seen as f64)]
    }

    fn snapshot(&self) -> Option<Vec<u8>> {
        Some(encode_frame(&[(0, self.scaler.delta()), (TAG_META_BASE, vec![self.seen as f64])]))
    }

    fn restore(&mut self, frame: &[u8]) -> samoa::Result<()> {
        let sections = decode_frame(frame)?;
        if let Some(stage) = section(&sections, 0) {
            self.scaler.apply_delta(stage);
        }
        // meta is absent in frames merged for a rescale: counters restart
        self.seen = section(&sections, TAG_META_BASE).map_or(0, |m| m[0] as u64);
        Ok(())
    }
}

fn stat_topology(p: usize) -> (Topology, StreamId) {
    let mut b = TopologyBuilder::new("stat-equiv");
    let stat = b.add_processor("stat", p, |_| StatShard::boxed());
    let entry = b.stream("entry", None, stat, Grouping::Shuffle);
    (b.build(), entry)
}

/// Deterministic instance stream, built once and replayed per run.
fn events(n: u64, seed: u64) -> Vec<Event> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|id| {
            let vals: Vec<f32> = (0..DIM).map(|_| (rng.gaussian() * 5.0 + 1.0) as f32).collect();
            Event::Instance { id, inst: Instance::dense(vals, Label::None) }
        })
        .collect()
}

/// Run the stat topology and collect `(pid, iid) → snapshot frame` plus
/// the summed `seen` report.
fn run_stat(
    eng: &ThreadedEngine,
    p: usize,
    evs: &[Event],
) -> (samoa::engine::metrics::EngineMetrics, Vec<((usize, usize), Vec<u8>)>, f64) {
    let (topo, entry) = stat_topology(p);
    let mut frames: Vec<((usize, usize), Vec<u8>)> = Vec::new();
    let mut seen = 0.0;
    let m = eng.run(&topo, entry, evs.iter().cloned(), |pid, iid, pr| {
        if let Some(f) = pr.snapshot() {
            frames.push(((pid, iid), f));
        }
        seen += pr.report().iter().find(|(k, _)| *k == "seen").map_or(0.0, |(_, v)| *v);
    });
    frames.sort_by_key(|(k, _)| *k);
    (m, frames, seen)
}

// ------------------------------------------------------ threaded engine

#[test]
fn threaded_kill_and_recover_is_bit_identical_when_nothing_dropped() {
    const N: u64 = 1_200; // p=2 shuffle → 600 deliveries per instance
    const INTERVAL: u64 = 128;
    let evs = events(N, 11);
    let (_, ref_frames, ref_seen) = run_stat(&ThreadedEngine::default(), 2, &evs);
    assert_eq!(ref_seen, N as f64);

    // kill at a checkpoint boundary (the kill check runs before that
    // boundary's snapshot, so the log still holds one full window) and
    // mid-window; pinned and stealing schedulers
    for (kill_at, expect_replayed) in [(512u64, 128u64), (500, 116)] {
        for workers in [None, Some(2)] {
            let mut eng = ThreadedEngine::default()
                .with_checkpoints(INTERVAL)
                .with_fault(0, 0, kill_at);
            if let Some(w) = workers {
                eng = eng.with_workers(w);
            }
            let (m, frames, seen) = run_stat(&eng, 2, &evs);
            let label = format!("kill@{kill_at} workers={workers:?}");
            assert_eq!(m.recovery.kills, 1, "{label}: fault did not fire");
            assert_eq!(m.recovery.restores, 1, "{label}");
            assert_eq!(m.recovery.replayed, expect_replayed, "{label}");
            assert_eq!(m.recovery.replay_dropped, 0, "{label}");
            assert!(m.recovery.checkpoints >= 6, "{label}: both instances checkpoint");
            assert!(m.recovery.checkpoint_bytes > 0, "{label}");
            assert_eq!(seen, N as f64, "{label}: every delivery must be accounted for");
            assert_eq!(
                frames, ref_frames,
                "{label}: recovered state differs from the undisturbed run"
            );
        }
    }
}

#[test]
fn threaded_tiny_replay_cap_loses_exactly_the_dropped_events() {
    const N: u64 = 1_200;
    let evs = events(N, 11);
    let (_, ref_frames, _) = run_stat(&ThreadedEngine::default(), 2, &evs);

    // no checkpoints at all: the replacement starts from a blank factory
    // instance plus whatever the 8-event log retained of its 300-event
    // history — the loss is visible and exactly bounded
    let eng = ThreadedEngine::default().with_fault(0, 0, 300).with_replay_cap(8);
    let (m, frames, seen) = run_stat(&eng, 2, &evs);
    assert_eq!(m.recovery.kills, 1);
    assert_eq!(m.recovery.restores, 1);
    assert_eq!(m.recovery.replayed, 8);
    assert_eq!(m.recovery.replay_dropped, 292);
    assert_eq!(seen, (N - 292) as f64, "totals must be short by exactly the dropped events");
    assert_ne!(frames[0], ref_frames[0], "the truncated victim must diverge");
    assert_eq!(frames[1], ref_frames[1], "the untouched shard must not");
}

#[test]
fn threaded_null_spec_kill_keeps_exact_delivery_totals() {
    const N: u64 = 1_600;
    let (topo, entry) = spec::build("null:p=2").unwrap();
    let source = (0..N).map(|id| Event::Instance {
        id,
        inst: Instance::dense(vec![0.5; 4], Label::None),
    });
    let eng = ThreadedEngine::default().with_checkpoints(128).with_fault(0, 0, 512);
    let mut seen = 0.0;
    let m = eng.run(&topo, entry, source, |_, _, pr| {
        seen += pr.report().iter().find(|(k, _)| *k == "seen").map_or(0.0, |(_, v)| *v);
    });
    assert_eq!(m.recovery.kills, 1);
    assert_eq!(m.recovery.restores, 1);
    assert_eq!(m.recovery.replayed, 128);
    assert_eq!(m.recovery.replay_dropped, 0);
    assert_eq!(seen, N as f64);
}

// ------------------------------------------------------- cluster engine

#[test]
fn cluster_worker_death_recovers_every_delivery() {
    const N: u64 = 1_600; // victim sink sees 800; dies on its 400th
    let (topo, entry) = spec::build("null:p=2:die=400:victim=0").unwrap();
    let source = (0..N).map(|id| Event::Instance {
        id,
        inst: Instance::dense(vec![0.25; 4], Label::None),
    });
    let eng = ClusterEngine::new().with_workers(2).with_checkpoints(64);
    let run = eng.run(&topo, entry, source).expect("cluster run with injected death");
    let r = &run.metrics.recovery;
    assert_eq!(r.kills, 1, "injected worker death did not fire");
    assert_eq!(r.restores, 1, "one held sink checkpoint should be re-shipped");
    assert!(r.replayed > 0, "the post-checkpoint delta must be re-driven");
    assert_eq!(r.replay_dropped, 0);
    assert!(r.checkpoints > 0);
    assert_eq!(run.kv_sum("seen"), N as f64, "every delivery must be accounted for");
}

#[test]
fn cluster_without_checkpoints_reports_unrecovered_death() {
    const N: u64 = 1_600;
    let (topo, entry) = spec::build("null:p=2:die=200:victim=0").unwrap();
    let source = (0..N).map(|id| Event::Instance {
        id,
        inst: Instance::dense(vec![0.25; 4], Label::None),
    });
    // recovery off (checkpoint_every == 0): the death surfaces as a hard
    // engine error instead of a silent partial run
    let err = ClusterEngine::new()
        .with_workers(2)
        .run(&topo, entry, source)
        .expect_err("worker death with recovery off must fail the run");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("worker") || msg.contains("cluster"),
        "error should point at the dead worker: {msg}"
    );
}

// ------------------------------------------------------------- rescale

#[test]
fn rescale_merges_shard_checkpoints_into_a_wider_topology() {
    const N: u64 = 1_200;
    let evs = events(N, 11);
    let (_, frames, _) = run_stat(&ThreadedEngine::default(), 2, &evs);
    assert_eq!(frames.len(), 2);

    let mut fresh = StandardScaler::new();
    fresh.bind(&schema());
    let mut scratch = Pipeline::new().then(fresh);
    let shard_frames: Vec<&[u8]> = frames.iter().map(|(_, f)| f.as_slice()).collect();
    let merged = merge_shard_frames(&shard_frames, &mut scratch).unwrap();
    let pooled = decode_frame(&merged).unwrap();
    let stage = section(&pooled, 0).unwrap();
    assert_eq!(stage[0], N as f64, "pooled moments must count every instance once");

    // seed all four shards of a p=4 topology with the merged state
    let seeds: Vec<(usize, usize, Vec<u8>)> = (0..4).map(|i| (0, i, merged.clone())).collect();
    let eng = ThreadedEngine::default().with_restore(seeds);
    let (topo, entry) = stat_topology(4);
    let mut frames4: Vec<Vec<u8>> = Vec::new();
    let m = eng.run(&topo, entry, std::iter::empty(), |_, _, pr| {
        if let Some(f) = pr.snapshot() {
            frames4.push(f);
        }
    });
    assert_eq!(m.recovery.restores, 4, "startup restores must be counted");
    assert_eq!(frames4.len(), 4);
    for f in &frames4 {
        let sections = decode_frame(f).unwrap();
        let got = section(&sections, 0).unwrap();
        let b0: Vec<u64> = stage.iter().map(|x| x.to_bits()).collect();
        let b1: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        assert_eq!(b0, b1, "every new shard must adopt the pooled statistics exactly");
    }
}
