//! Integration: every non-native backend must agree with the native rust
//! criterion implementations.
//!
//! * **SIMD vs native** — property tests over random `CounterBlock` /
//!   SDR / centroid inputs that run on every build, no artifacts needed:
//!   ≤ 1e-9 relative agreement and exact top-2 winner agreement (or a
//!   genuine tie within tolerance).
//! * **XLA vs native** — float32-tolerance checks against the AOT
//!   artifacts; skip (with a note) when `artifacts/` has not been built
//!   or the build carries only the in-tree XLA stub.

use samoa::common::Rng;
use samoa::core::criterion::{self, VarStats};
use samoa::core::observers::CounterBlock;
use samoa::runtime::{cluster, gain, registry, sdr, xla};

fn artifacts_available() -> bool {
    registry::artifacts_dir().is_some() && xla::AVAILABLE
}

/// Relative agreement with an absolute floor (tiny gains near 0).
fn close(n: f64, s: f64) -> bool {
    (n - s).abs() <= 1e-9 * (1.0 + n.abs())
}

fn random_block(rng: &mut Rng, v: u32, c: u32, n: usize) -> CounterBlock {
    let mut b = CounterBlock::new(v, c);
    for _ in 0..n {
        b.add(rng.below(v as usize) as u32, rng.below(c as usize) as u32, 1.0);
    }
    b
}

/// Like [`random_block`] but with fractional (weighted-instance) counts.
fn random_weighted_block(rng: &mut Rng, v: u32, c: u32, n: usize) -> CounterBlock {
    let mut b = CounterBlock::new(v, c);
    for _ in 0..n {
        let w = rng.below(1000) as f32 / 250.0; // 0.000..3.996
        b.add(rng.below(v as usize) as u32, rng.below(c as usize) as u32, w);
    }
    b
}

// ---------------------------------------------------------------------------
// SIMD vs native — always run
// ---------------------------------------------------------------------------

#[test]
fn simd_gains_match_native_property() {
    for seed in [1u64, 2, 3, 5, 8, 13] {
        let mut rng = Rng::new(seed);
        let mut blocks: Vec<CounterBlock> = Vec::new();
        for (v, c) in [(16u32, 8u32), (5, 3), (32, 2), (2, 8)] {
            for _ in 0..8 {
                blocks.push(random_block(&mut rng, v, c, 50 + rng.below(400)));
                blocks.push(random_weighted_block(&mut rng, v, c, 50 + rng.below(400)));
            }
        }
        // exotic shapes: no counts at all, and a single populated class
        blocks.push(CounterBlock::new(16, 8));
        let mut pure = CounterBlock::new(16, 8);
        for v in 0..16 {
            pure.add(v, 2, 5.0);
        }
        blocks.push(pure);
        let refs: Vec<&CounterBlock> = blocks.iter().collect();
        let native = gain::gains_native(&refs);
        let simd = gain::gains_simd(&refs);
        assert_eq!(native.len(), simd.len());
        for (i, (n, s)) in native.iter().zip(simd.iter()).enumerate() {
            assert!(close(*n, *s), "seed={seed} block {i}: native={n} simd={s}");
        }
        // the split decision itself must not move between backends
        let (ni, nb, _, n2) = gain::top2(&native);
        let (si, sb, _, s2) = gain::top2(&simd);
        assert!(
            ni == si || close(nb, sb),
            "seed={seed}: top-1 winner differs off-tie: native=({ni},{nb}) simd=({si},{sb})"
        );
        assert!(close(nb, sb) && close(n2, s2), "seed={seed}: top-2 gains diverged");
    }
}

#[test]
fn simd_sdr_surfaces_match_native_property() {
    for seed in [21u64, 22, 23, 25, 28, 33] {
        let mut rng = Rng::new(seed);
        let attrs: Vec<Vec<VarStats>> = (0..40)
            .map(|i| {
                // bin counts straddling the 4-lane width, incl. 1 and odd sizes
                let bins = [1usize, 2, 3, 5, 16, 64][i % 6];
                (0..bins)
                    .map(|_| {
                        let mut s = VarStats::default();
                        for _ in 0..rng.below(20) {
                            s.add(rng.gaussian() * 3.0 + 1.0, 1.0);
                        }
                        s // some bins stay empty (below(20) can be 0)
                    })
                    .collect()
            })
            .collect();
        let native = sdr::sdr_native(&attrs);
        let simd = sdr::sdr_simd(&attrs);
        assert_eq!(native.len(), simd.len());
        for (a, (n, s)) in native.iter().zip(simd.iter()).enumerate() {
            assert_eq!(n.len(), s.len());
            for (b, (nv, sv)) in n.iter().zip(s.iter()).enumerate() {
                assert!(close(*nv, *sv), "seed={seed} attr {a} bin {b}: native={nv} simd={sv}");
            }
        }
    }
}

#[test]
fn simd_cluster_assign_matches_native_property() {
    for seed in [41u64, 42, 43, 45, 48, 53] {
        let mut rng = Rng::new(seed);
        // d deliberately not lane-aligned; duplicate + dead centroids
        for d in [3usize, 7, 13, 33] {
            let (n, k) = (40usize, 12usize);
            let points: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
            let mut centers: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
            // centroid 5 duplicates centroid 1 exactly (a genuine tie)
            let dup: Vec<f32> = centers[d..2 * d].to_vec();
            centers[5 * d..6 * d].copy_from_slice(&dup);
            let mut weights = vec![1f32; k];
            weights[7] = 0.0; // dead slot
            let native = cluster::assign_native(&points, &centers, &weights, d);
            let simd = cluster::assign_simd(&points, &centers, &weights, d);
            for (p, (nv, sv)) in native.iter().zip(simd.iter()).enumerate() {
                assert!(
                    close(nv.1, sv.1),
                    "seed={seed} d={d} point {p}: native={nv:?} simd={sv:?}"
                );
                assert!(
                    nv.0 == sv.0 || close(nv.1, sv.1),
                    "seed={seed} d={d} point {p}: winner differs off-tie"
                );
                assert_ne!(sv.0, 7, "seed={seed} d={d}: dead slot won at point {p}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// XLA vs native — need built artifacts + real PJRT bindings
// ---------------------------------------------------------------------------

#[test]
fn xla_gains_match_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built or XLA stub build");
        return;
    }
    let mut rng = Rng::new(11);
    // more blocks than one chunk (64) to exercise chunking
    let blocks: Vec<CounterBlock> = (0..150)
        .map(|i| random_block(&mut rng, if i % 3 == 0 { 16 } else { 5 }, 8, 300))
        .collect();
    let refs: Vec<&CounterBlock> = blocks.iter().collect();
    let native = gain::gains_native(&refs);
    let xla = gain::gains_xla(&refs).expect("xla gain path");
    assert_eq!(native.len(), xla.len());
    for (i, (n, x)) in native.iter().zip(xla.iter()).enumerate() {
        assert!(
            (n - x).abs() < 1e-4,
            "gain mismatch at block {i}: native={n} xla={x}"
        );
    }
}

#[test]
fn xla_gains_empty_and_pure_blocks() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built or XLA stub build");
        return;
    }
    let empty = CounterBlock::new(16, 8);
    let mut pure = CounterBlock::new(16, 8);
    for v in 0..16 {
        pure.add(v, 2, 5.0);
    }
    let refs: Vec<&CounterBlock> = vec![&empty, &pure];
    let xla = gain::gains_xla(&refs).expect("xla gain path");
    assert!(xla[0].abs() < 1e-6, "empty block gain must be 0, got {}", xla[0]);
    assert!(xla[1].abs() < 1e-5, "single-class block gain must be 0, got {}", xla[1]);
}

#[test]
fn xla_sdr_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built or XLA stub build");
        return;
    }
    let mut rng = Rng::new(22);
    // 70 attributes (3 chunks of 32), 16-64 bins each
    let attrs: Vec<Vec<VarStats>> = (0..70)
        .map(|i| {
            let bins = if i % 2 == 0 { 16 } else { 64 };
            (0..bins)
                .map(|_| {
                    let mut s = VarStats::default();
                    for _ in 0..rng.below(20) {
                        s.add(rng.gaussian() * 3.0 + 1.0, 1.0);
                    }
                    s
                })
                .collect()
        })
        .collect();
    let native = sdr::sdr_native(&attrs);
    let xla = sdr::sdr_xla(&attrs).expect("xla sdr path");
    assert_eq!(native.len(), xla.len());
    for (a, (n, x)) in native.iter().zip(xla.iter()).enumerate() {
        assert_eq!(n.len(), x.len());
        for (b, (nv, xv)) in n.iter().zip(x.iter()).enumerate() {
            assert!(
                (nv - xv).abs() < 2e-3,
                "sdr mismatch at attr {a} bin {b}: native={nv} xla={xv}"
            );
        }
    }
}

#[test]
fn xla_cluster_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built or XLA stub build");
        return;
    }
    let mut rng = Rng::new(33);
    let (n, k, d) = (100, 60, 32);
    let points: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
    let centers: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
    let mut weights = vec![0f32; k];
    for w in weights.iter_mut().take(40) {
        *w = 1.0;
    }
    let native = cluster::assign_native(&points, &centers, &weights, d);
    let xla = cluster::assign_xla(&points, &centers, &weights, d).expect("xla cluster path");
    for (i, (nv, xv)) in native.iter().zip(xla.iter()).enumerate() {
        // distances must agree; indices may differ only on exact ties
        assert!(
            (nv.1 - xv.1).abs() < 1e-2 * (1.0 + nv.1),
            "dist mismatch at point {i}: native={:?} xla={:?}",
            nv,
            xv
        );
        assert!(xv.0 < 40, "dead slot won at point {i}: {:?}", xv);
    }
}

#[test]
fn gain_wrapper_uses_some_backend_and_is_consistent() {
    let mut rng = Rng::new(44);
    let blocks: Vec<CounterBlock> = (0..10).map(|_| random_block(&mut rng, 16, 8, 200)).collect();
    let refs: Vec<&CounterBlock> = blocks.iter().collect();
    let g = gain::gains(&refs);
    for (i, b) in blocks.iter().enumerate() {
        assert!((g[i] - criterion::info_gain(b)).abs() < 1e-4);
    }
}
