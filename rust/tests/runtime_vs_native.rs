//! Integration: the AOT XLA artifacts must agree with the native rust
//! criterion implementations to float32 tolerance. This is the rust-side
//! half of the correctness chain (python-side: pytest kernel-vs-ref).
//!
//! Skips (with a note) when `artifacts/` has not been built.

use samoa::common::Rng;
use samoa::core::criterion::{self, VarStats};
use samoa::core::observers::CounterBlock;
use samoa::runtime::{cluster, gain, registry, sdr};

fn artifacts_available() -> bool {
    registry::artifacts_dir().is_some()
}

fn random_block(rng: &mut Rng, v: u32, c: u32, n: usize) -> CounterBlock {
    let mut b = CounterBlock::new(v, c);
    for _ in 0..n {
        b.add(rng.below(v as usize) as u32, rng.below(c as usize) as u32, 1.0);
    }
    b
}

#[test]
fn xla_gains_match_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut rng = Rng::new(11);
    // more blocks than one chunk (64) to exercise chunking
    let blocks: Vec<CounterBlock> = (0..150)
        .map(|i| random_block(&mut rng, if i % 3 == 0 { 16 } else { 5 }, 8, 300))
        .collect();
    let refs: Vec<&CounterBlock> = blocks.iter().collect();
    let native = gain::gains_native(&refs);
    let xla = gain::gains_xla(&refs).expect("xla gain path");
    assert_eq!(native.len(), xla.len());
    for (i, (n, x)) in native.iter().zip(xla.iter()).enumerate() {
        assert!(
            (n - x).abs() < 1e-4,
            "gain mismatch at block {i}: native={n} xla={x}"
        );
    }
}

#[test]
fn xla_gains_empty_and_pure_blocks() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let empty = CounterBlock::new(16, 8);
    let mut pure = CounterBlock::new(16, 8);
    for v in 0..16 {
        pure.add(v, 2, 5.0);
    }
    let refs: Vec<&CounterBlock> = vec![&empty, &pure];
    let xla = gain::gains_xla(&refs).expect("xla gain path");
    assert!(xla[0].abs() < 1e-6, "empty block gain must be 0, got {}", xla[0]);
    assert!(xla[1].abs() < 1e-5, "single-class block gain must be 0, got {}", xla[1]);
}

#[test]
fn xla_sdr_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut rng = Rng::new(22);
    // 70 attributes (3 chunks of 32), 16-64 bins each
    let attrs: Vec<Vec<VarStats>> = (0..70)
        .map(|i| {
            let bins = if i % 2 == 0 { 16 } else { 64 };
            (0..bins)
                .map(|_| {
                    let mut s = VarStats::default();
                    for _ in 0..rng.below(20) {
                        s.add(rng.gaussian() * 3.0 + 1.0, 1.0);
                    }
                    s
                })
                .collect()
        })
        .collect();
    let native = sdr::sdr_native(&attrs);
    let xla = sdr::sdr_xla(&attrs).expect("xla sdr path");
    assert_eq!(native.len(), xla.len());
    for (a, (n, x)) in native.iter().zip(xla.iter()).enumerate() {
        assert_eq!(n.len(), x.len());
        for (b, (nv, xv)) in n.iter().zip(x.iter()).enumerate() {
            assert!(
                (nv - xv).abs() < 2e-3,
                "sdr mismatch at attr {a} bin {b}: native={nv} xla={xv}"
            );
        }
    }
}

#[test]
fn xla_cluster_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let mut rng = Rng::new(33);
    let (n, k, d) = (100, 60, 32);
    let points: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
    let centers: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32).collect();
    let mut weights = vec![0f32; k];
    for w in weights.iter_mut().take(40) {
        *w = 1.0;
    }
    let native = cluster::assign_native(&points, &centers, &weights, d);
    let xla = cluster::assign_xla(&points, &centers, &weights, d).expect("xla cluster path");
    for (i, (nv, xv)) in native.iter().zip(xla.iter()).enumerate() {
        // distances must agree; indices may differ only on exact ties
        assert!(
            (nv.1 - xv.1).abs() < 1e-2 * (1.0 + nv.1),
            "dist mismatch at point {i}: native={:?} xla={:?}",
            nv,
            xv
        );
        assert!(xv.0 < 40, "dead slot won at point {i}: {:?}", xv);
    }
}

#[test]
fn gain_wrapper_uses_some_backend_and_is_consistent() {
    let mut rng = Rng::new(44);
    let blocks: Vec<CounterBlock> = (0..10).map(|_| random_block(&mut rng, 16, 8, 200)).collect();
    let refs: Vec<&CounterBlock> = blocks.iter().collect();
    let g = gain::gains(&refs);
    for (i, b) in blocks.iter().enumerate() {
        assert!((g[i] - criterion::info_gain(b)).abs() < 1e-4);
    }
}
