//! Integration: the two ways of running a preprocessing pipeline — the
//! standalone `TransformedStream` wrapper and the `PipelineProcessor`
//! topology node — must produce *identical* prequential results for the
//! same source, pipeline and learner, under both the local and threaded
//! engines (p = 1: single shard of pipeline statistics, deterministic
//! arrival order).

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::evaluation::prequential::{
    prequential_run, EvalSink, EvaluatorProcessor, PrequentialConfig,
};
use samoa::preprocess::processor::build_prequential_topology;
use samoa::preprocess::{Discretizer, FeatureHasher, Pipeline, StandardScaler, TransformedStream};
use samoa::streams::waveform::WaveformGenerator;
use samoa::streams::StreamSource;
use samoa::topology::Event;

const SEED: u64 = 42;
const N: u64 = 8000;

/// The ≥3-stage pipeline of the acceptance criterion: hash → scale →
/// discretize. Fresh state per call so every path starts identically.
fn make_pipeline() -> Pipeline {
    Pipeline::new()
        .then(FeatureHasher::new(16))
        .then(StandardScaler::new())
        .then(Discretizer::new(8))
}

/// Path A: sequential prequential over the wrapped stream.
fn standalone_accuracy() -> f64 {
    let source = WaveformGenerator::classification(SEED);
    let mut ts = TransformedStream::new(source, make_pipeline());
    let schema = ts.schema().clone();
    let mut model = HoeffdingTree::new(schema, HTConfig::default());
    let r = prequential_run(
        &mut model,
        &mut ts,
        &PrequentialConfig { max_instances: N, report_every: N },
    );
    assert_eq!(r.instances, N);
    r.final_accuracy()
}

/// Path B: the same pipeline as a topology node on `engine`.
fn topology_accuracy(threaded: bool) -> f64 {
    let mut source = WaveformGenerator::classification(SEED);
    let schema = source.schema().clone();
    let sink = EvalSink::new(schema.n_classes(), 1.0, N);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_prequential_topology(
        &schema,
        1,
        |_| make_pipeline(),
        |s| Box::new(HoeffdingTree::new(s.clone(), HTConfig::default())),
        move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
    );
    let events =
        (0..N).map_while(|id| source.next_instance().map(|inst| Event::Instance { id, inst }));
    let m = if threaded {
        ThreadedEngine::default().run(&topo, handles.entry, events, |_, _, _| {})
    } else {
        LocalEngine::new().run(&topo, handles.entry, events, |_| {})
    };
    assert_eq!(m.source_instances, N);
    assert_eq!(m.streams[handles.prediction.0].events, N);
    sink.accuracy()
}

#[test]
fn standalone_and_topology_paths_identical_under_local_engine() {
    let a = standalone_accuracy();
    let b = topology_accuracy(false);
    assert!(
        (a - b).abs() < 1e-12,
        "standalone accuracy {a} != local-topology accuracy {b}"
    );
    // the pipeline preserves enough waveform signal to beat chance (1/3)
    assert!(a > 0.4, "accuracy {a} suspiciously low");
}

#[test]
fn local_and_threaded_topologies_identical() {
    let a = topology_accuracy(false);
    let b = topology_accuracy(true);
    assert!(
        (a - b).abs() < 1e-12,
        "local accuracy {a} != threaded accuracy {b}"
    );
}

#[test]
fn filters_drop_instances_consistently() {
    // a TopKFilter never drops whole instances (it prunes attributes), but
    // the wrapper must also cope with pipelines on finite streams; run a
    // 4-stage pipeline incl. topk end-to-end as a smoke check.
    use samoa::preprocess::TopKFilter;
    let source = WaveformGenerator::classification(7);
    let pl = Pipeline::new()
        .then(FeatureHasher::new(32))
        .then(TopKFilter::new(12))
        .then(StandardScaler::new())
        .then(Discretizer::new(6));
    let mut ts = TransformedStream::new(source, pl);
    let schema = ts.schema().clone();
    assert_eq!(schema.n_attributes(), 32);
    let mut model = HoeffdingTree::new(schema, HTConfig::default());
    let r = prequential_run(
        &mut model,
        &mut ts,
        &PrequentialConfig { max_instances: 3000, report_every: 3000 },
    );
    assert_eq!(r.instances, 3000);
}
