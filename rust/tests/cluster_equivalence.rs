//! Golden equivalence of the cluster engine: a topology executed across
//! worker shards over real sockets — every delivery serialized through
//! the wire codec — must produce *bit-identical* results to the
//! sequential local engine at every worker count. Pinned for the three
//! paper workloads: VHT (control-plane split rounds + delayed feedback),
//! AMRules/VAMR (rule broadcast protocol), and StatsSync (exact
//! delta/broadcast round counts, including the staged-shutdown straggler
//! flush).
//!
//! Thread-mode cluster runs are used (test binaries cannot re-exec
//! themselves into worker processes); the full wire protocol — codec,
//! lanes, windows, staged shutdown — is identical in both modes.

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use samoa::classifiers::vht::{self, VhtConfig};
use samoa::core::model::Classifier;
use samoa::core::Schema;
use samoa::engine::{ClusterEngine, ClusterRun, EngineMetrics, LocalEngine, PeerMode};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::preprocess::processor::{build_prequential_topology_head, LearnerHead};
use samoa::preprocess::{Pipeline, StandardScaler, SyncPolicy};
use samoa::regressors::amrules::AMRulesConfig;
use samoa::regressors::vamr;
use samoa::streams::datasets::ElectricityRegStream;
use samoa::streams::random_tree::RandomTreeGenerator;
use samoa::streams::StreamSource;
use samoa::topology::{Event, Processor};

const N: u64 = 6_000;
const SEED: u64 = 11;

/// Assert the per-stream event/byte totals match exactly — the cluster
/// coordinator routes with the local engine's own code path, so any
/// divergence is a protocol-ordering bug, not noise.
fn assert_streams_identical(local: &EngineMetrics, cluster: &ClusterRun, label: &str) {
    assert_eq!(local.streams.len(), cluster.metrics.streams.len(), "{label}: stream count");
    for (s, (a, b)) in local.streams.iter().zip(&cluster.metrics.streams).enumerate() {
        assert_eq!(a.events, b.events, "{label}: stream {s} events");
        assert_eq!(a.bytes, b.bytes, "{label}: stream {s} bytes");
    }
    assert_eq!(local.source_instances, cluster.metrics.source_instances, "{label}: sources");
    for (p, (ra, rb)) in
        local.per_instance.iter().zip(&cluster.metrics.per_instance).enumerate()
    {
        for (i, (ia, ib)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(
                ia.events_processed, ib.events_processed,
                "{label}: instance ({p},{i}) processed"
            );
        }
    }
}

// ------------------------------------------------------------------ VHT

fn vht_source(n: u64) -> impl Iterator<Item = Event> {
    let mut stream = RandomTreeGenerator::new(5, 5, 2, SEED);
    (0..n).map(move |id| Event::Instance { id, inst: stream.next_instance().unwrap() })
}

fn vht_config(p: usize) -> VhtConfig {
    // Delayed feedback exercises the coordinator's delayed-release path.
    VhtConfig { parallelism: p, feedback_delay: 50, ..Default::default() }
}

#[test]
fn vht_totals_and_model_bit_identical_to_local() {
    let schema = RandomTreeGenerator::new(5, 5, 2, SEED).schema().clone();
    for p in [1usize, 2, 4] {
        let config = vht_config(p);

        let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = vht::build_topology(&schema, &config, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
        let mut local_splits = None;
        let ma = handles.ma.0;
        let local = LocalEngine::new().run(&topo, handles.entry, vht_source(N), |instances| {
            local_splits = instances[ma][0]
                .report()
                .iter()
                .find(|(k, _)| *k == "splits")
                .map(|(_, v)| *v);
        });
        let local_acc = sink.accuracy();
        let local_n = sink.classification.lock().unwrap().n;
        let local_correct = sink.classification.lock().unwrap().correct;

        for workers in [1usize, 2, 4] {
            let (topo2, h2) = vht::build_topology(&schema, &config, {
                let schema = schema.clone();
                move |_| {
                    let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
                    Box::new(EvaluatorProcessor { sink })
                }
            });
            let run = ClusterEngine::new()
                .with_workers(workers)
                .run(&topo2, h2.entry, vht_source(N))
                .expect("cluster run");

            let label = format!("vht p={p} workers={workers}");
            assert_streams_identical(&local, &run, &label);
            assert_eq!(run.kv(h2.evaluator.0, 0, "n"), Some(local_n as f64), "{label}: n");
            assert_eq!(
                run.kv(h2.evaluator.0, 0, "correct"),
                Some(local_correct as f64),
                "{label}: correct"
            );
            assert_eq!(run.kv(h2.evaluator.0, 0, "accuracy"), Some(local_acc), "{label}: acc");
            assert_eq!(run.kv(h2.ma.0, 0, "splits"), local_splits, "{label}: splits");
            // real bytes crossed sockets
            assert!(run.metrics.cluster.total_bytes() > 0, "{label}: wire bytes");
            assert_eq!(run.metrics.cluster.workers, workers as u64, "{label}: workers");
        }
    }
}

// -------------------------------------------------------------- AMRules

fn amr_source(n: u64) -> impl Iterator<Item = Event> {
    let mut stream = ElectricityRegStream::with_limit(SEED, n);
    (0..n).map_while(move |id| {
        stream.next_instance().map(|inst| Event::Instance { id, inst })
    })
}

#[test]
fn vamr_totals_and_rmse_bit_identical_to_local() {
    let probe = ElectricityRegStream::with_limit(SEED, N);
    let schema = probe.schema().clone();
    let range = schema.label_range();

    for p in [1usize, 2, 4] {
        let sink = EvalSink::new(0, range, u64::MAX);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) =
            vamr::build_topology(&schema, &AMRulesConfig::default(), p, move |_| {
                Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
            });
        let local = LocalEngine::new().run(&topo, handles.entry, amr_source(N), |_| {});
        let local_rmse = sink.rmse();

        for workers in [1usize, 2, 4] {
            let (topo2, h2) =
                vamr::build_topology(&schema, &AMRulesConfig::default(), p, move |_| {
                    let sink = EvalSink::new(0, range, u64::MAX);
                    Box::new(EvaluatorProcessor { sink })
                });
            let run = ClusterEngine::new()
                .with_workers(workers)
                .run(&topo2, h2.entry, amr_source(N))
                .expect("cluster run");

            let label = format!("vamr p={p} workers={workers}");
            assert_streams_identical(&local, &run, &label);
            assert_eq!(run.kv(h2.evaluator.0, 0, "rmse"), Some(local_rmse), "{label}: rmse");
        }
    }
}

// ------------------------------------------------------------ StatsSync

fn sync_topology(
    schema: &Schema,
    p: usize,
) -> (samoa::topology::Topology, samoa::preprocess::processor::PreprocessHandles) {
    build_prequential_topology_head(
        schema,
        p,
        Some(SyncPolicy::Count(64)),
        |_| Pipeline::new().then(StandardScaler::new()),
        LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn Classifier> {
            Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
        })),
        {
            let n_classes = schema.n_classes();
            move |_| {
                let sink = EvalSink::new(n_classes, 1.0, u64::MAX);
                Box::new(EvaluatorProcessor { sink })
            }
        },
    )
}

fn waveform_source(n: u64) -> impl Iterator<Item = Event> {
    let mut stream = samoa::streams::waveform::WaveformGenerator::classification(SEED);
    (0..n).map(move |id| Event::Instance { id, inst: stream.next_instance().unwrap() })
}

#[test]
fn stats_sync_round_counts_bit_identical_to_local() {
    let schema =
        samoa::streams::waveform::WaveformGenerator::classification(SEED).schema().clone();
    let p = 4usize;

    let (topo, handles) = sync_topology(&schema, p);
    let stats_pid = handles.stats.expect("sync topology has an aggregator").0;
    let mut local_kv: Vec<(String, f64)> = Vec::new();
    let local = LocalEngine::new().run(&topo, handles.entry, waveform_source(N), |instances| {
        local_kv = instances[stats_pid][0]
            .report()
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
    });
    assert!(
        local_kv.iter().any(|(k, v)| k == "deltas_merged" && *v > 0.0),
        "local run must complete sync rounds, got {local_kv:?}"
    );

    for workers in [1usize, 2, 4] {
        let (topo2, h2) = sync_topology(&schema, p);
        let run = ClusterEngine::new()
            .with_workers(workers)
            .run(&topo2, h2.entry, waveform_source(N))
            .expect("cluster run");

        let label = format!("sync p={p} workers={workers}");
        assert_streams_identical(&local, &run, &label);
        let stats2 = h2.stats.unwrap().0;
        for (k, v) in &local_kv {
            assert_eq!(
                run.kv(stats2, 0, k),
                Some(*v),
                "{label}: {k} (delta/broadcast rounds must survive staged shutdown)"
            );
        }
        // the evaluator's report made it back over the collect phase
        let eval_n = run.kv(h2.evaluator.0, 0, "n");
        assert!(eval_n.is_some(), "{label}: evaluator report present");
    }
}

// ------------------------------------------------------ peer data plane
//
// `with_peer(Deterministic)` ships eligible data deliveries on direct
// worker↔worker links while the coordinator keeps sequencing slots; the
// results must stay bit-identical to the local engine at every worker
// count, for all three paper workloads. VHT is the sharpest probe: its
// delayed feedback stream must stay coordinator-routed (delay > 0 is
// peer-ineligible) while the attribute fan-out rides the peer links.

#[test]
fn vht_peer_det_bit_identical_to_local() {
    let schema = RandomTreeGenerator::new(5, 5, 2, SEED).schema().clone();
    let p = 2usize;
    let config = vht_config(p);

    let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = vht::build_topology(&schema, &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let local = LocalEngine::new().run(&topo, handles.entry, vht_source(N), |_| {});
    let local_acc = sink.accuracy();

    for workers in [1usize, 2, 4] {
        let (topo2, h2) = vht::build_topology(&schema, &config, {
            let schema = schema.clone();
            move |_| {
                let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
                Box::new(EvaluatorProcessor { sink })
            }
        });
        let run = ClusterEngine::new()
            .with_workers(workers)
            .with_peer(PeerMode::Deterministic)
            .run(&topo2, h2.entry, vht_source(N))
            .expect("peer cluster run");

        let label = format!("vht peer-det p={p} workers={workers}");
        assert_streams_identical(&local, &run, &label);
        assert_eq!(run.kv(h2.evaluator.0, 0, "accuracy"), Some(local_acc), "{label}: acc");
        if workers > 1 {
            assert!(run.metrics.cluster.peer_frames() > 0, "{label}: peer links carried data");
            assert!(!run.metrics.cluster.peer_links.is_empty(), "{label}: per-link counters");
        }
    }
}

#[test]
fn vamr_peer_det_bit_identical_to_local() {
    let probe = ElectricityRegStream::with_limit(SEED, N);
    let schema = probe.schema().clone();
    let range = schema.label_range();
    let p = 2usize;

    let sink = EvalSink::new(0, range, u64::MAX);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = vamr::build_topology(&schema, &AMRulesConfig::default(), p, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let local = LocalEngine::new().run(&topo, handles.entry, amr_source(N), |_| {});
    let local_rmse = sink.rmse();

    for workers in [1usize, 2, 4] {
        let (topo2, h2) =
            vamr::build_topology(&schema, &AMRulesConfig::default(), p, move |_| {
                let sink = EvalSink::new(0, range, u64::MAX);
                Box::new(EvaluatorProcessor { sink })
            });
        let run = ClusterEngine::new()
            .with_workers(workers)
            .with_peer(PeerMode::Deterministic)
            .run(&topo2, h2.entry, amr_source(N))
            .expect("peer cluster run");

        let label = format!("vamr peer-det p={p} workers={workers}");
        assert_streams_identical(&local, &run, &label);
        assert_eq!(run.kv(h2.evaluator.0, 0, "rmse"), Some(local_rmse), "{label}: rmse");
    }
}

#[test]
fn stats_sync_peer_det_bit_identical_to_local() {
    let schema =
        samoa::streams::waveform::WaveformGenerator::classification(SEED).schema().clone();
    let p = 4usize;

    let (topo, handles) = sync_topology(&schema, p);
    let stats_pid = handles.stats.expect("sync topology has an aggregator").0;
    let mut local_kv: Vec<(String, f64)> = Vec::new();
    let local = LocalEngine::new().run(&topo, handles.entry, waveform_source(N), |instances| {
        local_kv = instances[stats_pid][0]
            .report()
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
    });

    for workers in [1usize, 2, 4] {
        let (topo2, h2) = sync_topology(&schema, p);
        let run = ClusterEngine::new()
            .with_workers(workers)
            .with_peer(PeerMode::Deterministic)
            .run(&topo2, h2.entry, waveform_source(N))
            .expect("peer cluster run");

        let label = format!("sync peer-det p={p} workers={workers}");
        assert_streams_identical(&local, &run, &label);
        let stats2 = h2.stats.unwrap().0;
        for (k, v) in &local_kv {
            assert_eq!(run.kv(stats2, 0, k), Some(*v), "{label}: {k}");
        }
    }
}

#[test]
fn vht_peer_fast_conserves_totals() {
    // Fast mode drops the coordinator's slot tokens: each receiver
    // merges peer frames in arrival order, so model-state equality is
    // NOT promised — but every delivery still happens exactly once and
    // the coordinator still meters per-stream totals in global send
    // order, so those stay identical to local.
    let schema = RandomTreeGenerator::new(5, 5, 2, SEED).schema().clone();
    let config = vht_config(2);
    let (topo, handles) = vht::build_topology(&schema, &config, {
        let schema = schema.clone();
        move |_| {
            let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
            Box::new(EvaluatorProcessor { sink })
        }
    });
    let local = LocalEngine::new().run(&topo, handles.entry, vht_source(N), |_| {});

    for workers in [2usize, 4] {
        let (topo2, h2) = vht::build_topology(&schema, &config, {
            let schema = schema.clone();
            move |_| {
                let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
                Box::new(EvaluatorProcessor { sink })
            }
        });
        let run = ClusterEngine::new()
            .with_workers(workers)
            .with_peer(PeerMode::Fast)
            .run(&topo2, h2.entry, vht_source(N))
            .expect("peer-fast cluster run");

        let label = format!("vht peer-fast workers={workers}");
        for (s, (a, b)) in local.streams.iter().zip(&run.metrics.streams).enumerate() {
            assert_eq!(a.events, b.events, "{label}: stream {s} events");
            assert_eq!(a.bytes, b.bytes, "{label}: stream {s} bytes");
        }
        assert_eq!(local.source_instances, run.metrics.source_instances, "{label}: sources");
        assert!(run.metrics.cluster.peer_frames() > 0, "{label}: peer links carried data");
        // the evaluator saw every prediction exactly once
        let local_n: f64 = N as f64;
        let eval_n = run.kv(h2.evaluator.0, 0, "n").unwrap_or(0.0);
        assert!(
            eval_n <= local_n && eval_n > 0.0,
            "{label}: evaluator n = {eval_n} (local {local_n})"
        );
    }
}

// ------------------------------------- peer-routed Shuffle + injection
//
// The rr-cursor activation: a Shuffle stream with parallelism > 1 and a
// sole emitter routes on the worker's seeded round-robin cursor and
// ships peer-to-peer. Deterministic mode must reproduce the local
// engine's round-robin split bit-for-bit at every worker count.

fn relay_source(n: u64) -> impl Iterator<Item = Event> {
    use samoa::core::instance::{Instance, Label};
    (0..n).map(|id| Event::Instance { id, inst: Instance::dense(vec![0.25; 8], Label::None) })
}

#[test]
fn relay_shuffle_peer_det_bit_identical_to_local() {
    use samoa::engine::cluster::spec;
    let n = 2_000u64;
    for p in [2usize, 4] {
        let spec_str = format!("relay:p={p}:g=shuffle");
        let (topo, entry) = spec::build(&spec_str).expect("relay spec");
        let mut local_seen: Vec<f64> = Vec::new();
        let local = LocalEngine::new().run(&topo, entry, relay_source(n), |instances| {
            local_seen = instances[1]
                .iter()
                .map(|s| s.report().iter().find(|(k, _)| *k == "seen").map_or(0.0, |(_, v)| *v))
                .collect();
        });
        assert_eq!(local_seen.iter().sum::<f64>(), n as f64, "local shuffle lost events");

        for workers in [1usize, 2, 4] {
            let (topo2, entry2) = spec::build(&spec_str).expect("relay spec");
            let run = ClusterEngine::new()
                .with_workers(workers)
                .with_peer(PeerMode::Deterministic)
                .run(&topo2, entry2, relay_source(n))
                .expect("peer cluster run");

            let label = format!("relay shuffle p={p} workers={workers}");
            assert_streams_identical(&local, &run, &label);
            for (i, &seen) in local_seen.iter().enumerate() {
                assert_eq!(run.kv(1, i, "seen"), Some(seen), "{label}: sink {i} rr split");
            }
            if workers > 1 {
                assert!(
                    run.metrics.cluster.peer_frames() > 0,
                    "{label}: shuffle hop must ride the peer plane"
                );
            }
        }
    }
}

#[test]
fn vht_pipelined_injection_matches_local_at_same_window() {
    // Pipelined injection changes the delivery interleaving, so the
    // equivalence contract is cluster@w == local at the SAME injection
    // window: both engines release the barrier every 8 source events.
    let schema = RandomTreeGenerator::new(5, 5, 2, SEED).schema().clone();
    let config = vht_config(2);
    let (topo, handles) = vht::build_topology(&schema, &config, {
        let schema = schema.clone();
        move |_| {
            let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
            Box::new(EvaluatorProcessor { sink })
        }
    });
    let ev = handles.evaluator.0;
    let mut local_acc = None;
    let local = LocalEngine::new().with_inject_window(8).run(
        &topo,
        handles.entry,
        vht_source(N),
        |instances| {
            local_acc = instances[ev][0]
                .report()
                .iter()
                .find(|(k, _)| *k == "accuracy")
                .map(|(_, v)| *v);
        },
    );

    for workers in [1usize, 2, 4] {
        let (topo2, h2) = vht::build_topology(&schema, &config, {
            let schema = schema.clone();
            move |_| {
                let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
                Box::new(EvaluatorProcessor { sink })
            }
        });
        let run = ClusterEngine::new()
            .with_workers(workers)
            .with_inject_window(8)
            .run(&topo2, h2.entry, vht_source(N))
            .expect("injected cluster run");

        let label = format!("vht inject=8 workers={workers}");
        assert_streams_identical(&local, &run, &label);
        assert_eq!(run.kv(h2.evaluator.0, 0, "accuracy"), local_acc, "{label}: accuracy");
        assert!(run.metrics.flow.inject_frames > 0, "{label}: FRAME_INJECT batches shipped");
    }
}

#[test]
fn worker_kill_recovers_with_pipelined_injection_in_flight() {
    // A worker dies while FRAME_INJECT batches are in flight: the
    // coordinator must skip the dead worker's batched pendings, re-drive
    // their replay-log entries individually, and finish with every
    // delivery accounted for. The engine is built through EngineConfig
    // to exercise the unified surface end-to-end.
    use samoa::engine::cluster::spec;
    use samoa::engine::EngineConfig;
    let n = 2_000u64;
    let (topo, entry) = spec::build("relay:p=2:die=400:victim=0").expect("relay spec");
    let cfg = EngineConfig::parse("workers=2,inject=8,ckpt=64").expect("config spec");
    let run = ClusterEngine::from_config(&cfg)
        .run(&topo, entry, relay_source(n))
        .expect("recovering cluster run");

    let r = &run.metrics.recovery;
    assert_eq!(r.kills, 1, "injected worker death must fire");
    assert!(r.replayed > 0, "replay log must re-drive the lost delta");
    assert_eq!(r.replay_dropped, 0, "replay cap must cover the delta");
    assert!(run.metrics.flow.inject_frames > 0, "kill must land with batches in flight");
    let seen: f64 = (0..2).map(|i| run.kv(1, i, "seen").unwrap_or(0.0)).sum();
    assert_eq!(seen, n as f64, "every delivery accounted for after recovery");
    assert_eq!(run.kv(0, 0, "relayed"), Some(n as f64), "fwd state restored + replayed");
}

// ------------------------------------------- backpressure window (small)

#[test]
fn small_window_changes_nothing_but_stall_counters() {
    let schema = RandomTreeGenerator::new(5, 5, 2, SEED).schema().clone();
    let config = vht_config(2);
    let (topo, handles) = vht::build_topology(&schema, &config, {
        let schema = schema.clone();
        move |_| {
            let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
            Box::new(EvaluatorProcessor { sink })
        }
    });
    let wide = ClusterEngine::new()
        .with_workers(2)
        .run(&topo, handles.entry, vht_source(2_000))
        .expect("wide run");

    let (topo2, h2) = vht::build_topology(&schema, &config, {
        let schema = schema.clone();
        move |_| {
            let sink = EvalSink::new(schema.n_classes(), 1.0, u64::MAX);
            Box::new(EvaluatorProcessor { sink })
        }
    });
    let narrow = ClusterEngine::new()
        .with_workers(2)
        .with_window(2)
        .run(&topo2, h2.entry, vht_source(2_000))
        .expect("narrow run");

    for (s, (a, b)) in wide.metrics.streams.iter().zip(&narrow.metrics.streams).enumerate() {
        assert_eq!(a.events, b.events, "stream {s} events under window=2");
        assert_eq!(a.bytes, b.bytes, "stream {s} bytes under window=2");
    }
    assert_eq!(
        wide.kv(handles.evaluator.0, 0, "accuracy"),
        narrow.kv(h2.evaluator.0, 0, "accuracy"),
        "window size must not change results"
    );
    assert!(
        narrow.metrics.flow.backpressure_stalls > wide.metrics.flow.backpressure_stalls,
        "window=2 must record more socket-window stalls"
    );
}
