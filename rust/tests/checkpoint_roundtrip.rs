//! Checkpoint-frame and snapshot/restore roundtrip properties — the
//! bit-identity contract the recovery layer (`engine::checkpoint` +
//! `Processor::snapshot`/`restore`) rests on, probed at three layers
//! with the same discipline as `codec_roundtrip.rs`:
//!
//! * the frame codec itself: NaN payload bits, `-0.0` vs `+0.0` under
//!   the sparse form, re-encode byte-stability, every truncation and a
//!   corrupted header rejected;
//! * every `MergeableState` impl: `delta()` pushed through
//!   `encode_frame`/`decode_frame` and adopted by a fresh instance via
//!   `apply_delta` must reproduce the payload bits exactly;
//! * every `Processor::snapshot` impl (pipeline shard, stats-sync,
//!   evaluator, VHT model aggregator): snapshot → fresh factory build →
//!   `restore` → re-snapshot must reproduce the frame byte-for-byte —
//!   exactly what a respawn does before replaying the delta.

use samoa::common::Rng;
use samoa::core::instance::{Instance, Label};
use samoa::core::Schema;
use samoa::engine::checkpoint::{
    decode_frame, encode_frame, merge_shard_frames, section, CheckpointStore, TAG_META_BASE,
};
use samoa::engine::cluster::spec;
use samoa::engine::LocalEngine;
use samoa::preprocess::merge::payloads_close;
use samoa::preprocess::{
    CountMinSketch, Discretizer, MergeableState, MinMaxScaler, MisraGries, Pipeline,
    StandardScaler, Transform,
};
use samoa::topology::{Event, Processor};

const DIM: usize = 3;

fn schema() -> Schema {
    Schema::classification("t", Schema::all_numeric(DIM), 2)
}

fn random_instance(rng: &mut Rng) -> Instance {
    let vals: Vec<f32> = (0..DIM).map(|_| (rng.gaussian() * 5.0 + 1.0) as f32).collect();
    Instance::dense(vals, Label::None)
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Distinct quiet/signalling-style payload patterns plus the canonical
/// NaN — all must survive the frame codec bit-for-bit.
fn nan_patterns() -> Vec<f64> {
    [0x7FF8_0000_0000_0001u64, 0x7FF8_DEAD_BEEF_0001, 0xFFF8_0000_0000_0042]
        .iter()
        .map(|&b| f64::from_bits(b))
        .chain([f64::NAN])
        .collect()
}

// Deterministic state builders, mirroring `merge_properties.rs`: the
// transforms are not `Clone`, so "copies" are re-fed seeded streams.

fn scaler(seed: u64, n: usize) -> StandardScaler {
    let mut s = StandardScaler::new();
    s.bind(&schema());
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        s.transform(random_instance(&mut rng)).unwrap();
    }
    s
}

fn minmax(seed: u64, n: usize) -> MinMaxScaler {
    let mut s = MinMaxScaler::new();
    s.bind(&schema());
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        s.transform(random_instance(&mut rng)).unwrap();
    }
    s
}

fn discretizer(warm_seed: u64, seed: u64, n: usize) -> Discretizer {
    let mut d = Discretizer::with_resolution(4, 32, 64);
    d.bind(&schema());
    let mut wrng = Rng::new(warm_seed);
    for _ in 0..32 {
        d.transform(random_instance(&mut wrng)).unwrap();
    }
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        d.transform(random_instance(&mut rng)).unwrap();
    }
    d
}

fn countmin(seed: u64, n: usize) -> CountMinSketch {
    let mut cm = CountMinSketch::new(128, 4);
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        cm.add(rng.below(200) as u64, 1 + rng.below(3) as u64);
    }
    cm
}

fn misra_gries(seed: u64, n: usize) -> MisraGries {
    let mut mg = MisraGries::new(12);
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        let x = if rng.below(2) == 0 { rng.below(4) as u64 } else { 10 + rng.below(400) as u64 };
        mg.add(x);
    }
    mg
}

// --------------------------------------------------------- frame codec

#[test]
fn frame_preserves_every_bit_pattern_dense_and_sparse() {
    let mut dense = nan_patterns();
    dense.extend([0.0, -0.0, f64::INFINITY, f64::NEG_INFINITY, 5e-324, f64::MIN_POSITIVE, 1.5]);
    // mostly zeros → stored in the sparse form; planted non-zeros must
    // come back bit-identical, including -0.0 (whose bits are non-zero)
    let mut sparse = vec![0.0; 300];
    for (i, v) in nan_patterns().into_iter().enumerate() {
        sparse[17 * (i + 1)] = v;
    }
    sparse[250] = -0.0;
    sparse[299] = 5e-324;
    let sections = vec![(0u32, dense), (3u32, sparse), (TAG_META_BASE, vec![42.0])];

    let frame = encode_frame(&sections);
    let back = decode_frame(&frame).unwrap();
    assert_eq!(back.len(), sections.len());
    for ((t0, p0), (t1, p1)) in sections.iter().zip(&back) {
        assert_eq!(t0, t1);
        assert_eq!(bits(p0), bits(p1), "tag {t0}: payload bits changed across the frame codec");
    }
    assert_eq!(encode_frame(&back), frame, "decode → re-encode must be byte-stable");
}

#[test]
fn every_truncation_and_header_corruption_rejected() {
    let sections = vec![
        (0u32, vec![1.0, -2.5, 3.25]),
        (1u32, {
            let mut v = vec![0.0; 64];
            v[5] = f64::NAN;
            v[63] = -0.0;
            v
        }),
        (TAG_META_BASE, vec![7.0, 0.0]),
    ];
    let frame = encode_frame(&sections);
    assert!(decode_frame(&frame).is_ok());
    for cut in 0..frame.len() {
        assert!(decode_frame(&frame[..cut]).is_err(), "truncated frame (len {cut}) accepted");
    }
    let mut bad = frame.clone();
    bad[0] ^= 0xFF;
    assert!(decode_frame(&bad).is_err(), "frame with a wrong version byte accepted");
}

// --------------------------------------------------- MergeableState laws

/// `delta()` → frame codec → `apply_delta` on a fresh instance must be
/// bit-identical end to end (the shard-restore path of a rescale).
fn assert_delta_roundtrips<T: MergeableState>(label: &str, orig: &T, fresh: &mut T) {
    let d = orig.delta();
    let sections = decode_frame(&encode_frame(&[(9, d.clone())])).unwrap();
    let got = section(&sections, 9).unwrap();
    assert_eq!(bits(&d), bits(got), "{label}: frame codec changed the delta payload bits");
    fresh.apply_delta(got);
    assert_eq!(
        bits(&fresh.delta()),
        bits(&d),
        "{label}: snapshot → restore on a fresh instance is not bit-identical"
    );
}

#[test]
fn every_mergeable_state_restores_bit_identical() {
    for seed in 0..6u64 {
        let n = 300 + 37 * seed as usize;

        let mut fresh = StandardScaler::new();
        fresh.bind(&schema());
        assert_delta_roundtrips("StandardScaler", &scaler(100 + seed, n), &mut fresh);

        let mut fresh = MinMaxScaler::new();
        fresh.bind(&schema());
        assert_delta_roundtrips("MinMaxScaler", &minmax(200 + seed, n), &mut fresh);

        let mut fresh = Discretizer::with_resolution(4, 32, 64);
        fresh.bind(&schema());
        assert_delta_roundtrips("Discretizer", &discretizer(7, 300 + seed, n), &mut fresh);

        let mut fresh = CountMinSketch::new(128, 4);
        assert_delta_roundtrips("CountMinSketch", &countmin(400 + seed, n), &mut fresh);

        let mut fresh = MisraGries::new(12);
        assert_delta_roundtrips("MisraGries", &misra_gries(500 + seed, n), &mut fresh);
    }
}

// ----------------------------------------------- Processor::snapshot impls

/// Run a spec topology on the local engine, snapshot every instance at
/// the final drain, then do exactly what a respawn does: build a fresh
/// instance from the topology factory, `restore` the frame, and demand
/// the re-snapshot reproduce it byte-for-byte.
fn snapshot_roundtrip_topology(spec_str: &str, stream: &str, n: u64, min_snaps: usize) {
    let (topo, entry) = spec::build(spec_str).unwrap();
    let mut src = samoa::experiments::dataset_stream(stream, 7);
    let source =
        (0..n).map_while(move |id| src.next_instance().map(|inst| Event::Instance { id, inst }));
    let mut snaps: Vec<(usize, usize, Vec<u8>)> = Vec::new();
    LocalEngine::new().run(&topo, entry, source, |procs| {
        snaps.clear();
        for (pid, col) in procs.iter().enumerate() {
            for (iid, inst) in col.iter().enumerate() {
                if let Some(frame) = inst.snapshot() {
                    snaps.push((pid, iid, frame));
                }
            }
        }
    });
    assert!(
        snaps.len() >= min_snaps,
        "{spec_str}: expected ≥{min_snaps} snapshotting instances, got {}",
        snaps.len()
    );
    for (pid, iid, frame) in snaps {
        decode_frame(&frame).unwrap_or_else(|e| {
            panic!("{spec_str} pid {pid} iid {iid}: snapshot frame does not decode: {e}")
        });
        let mut fresh = (topo.processors[pid].factory)(iid);
        fresh.restore(&frame).unwrap_or_else(|e| {
            panic!("{spec_str} pid {pid} iid {iid} ({}): restore failed: {e}", fresh.name())
        });
        let again = fresh
            .snapshot()
            .unwrap_or_else(|| panic!("{spec_str} pid {pid} iid {iid}: restored instance is mute"));
        assert_eq!(
            again,
            frame,
            "{spec_str} pid {pid} iid {iid} ({}): restore → snapshot not byte-identical",
            fresh.name()
        );
    }
}

#[test]
fn sync_topology_snapshots_restore_byte_identical() {
    // pipeline shards ×2 + evaluator + stats-sync all snapshot (the
    // Hoeffding-tree learner intentionally does not — see engine docs)
    snapshot_roundtrip_topology("sync:stream=elec:p=2:interval=64:seed=7", "elec", 1_500, 4);
}

#[test]
fn vht_topology_snapshots_restore_byte_identical() {
    // model aggregator (7 recovery counters) + evaluator
    snapshot_roundtrip_topology("vht:stream=elec:p=2:seed=7", "elec", 1_200, 2);
}

// -------------------------------------------------- store + shard rescale

#[test]
fn checkpoint_store_tracks_latest_frame_per_instance() {
    let mut store = CheckpointStore::new();
    assert!(store.is_empty());
    store.put(0, 0, vec![1, 2, 3]);
    store.put(0, 1, vec![4]);
    store.put(2, 0, vec![5, 6]);
    store.put(0, 0, vec![9, 9]); // overwrite keeps only the latest frame
    assert_eq!(store.len(), 3);
    assert_eq!(store.get(0, 0), Some(&[9u8, 9][..]));
    assert_eq!(store.get(1, 0), None);
    let shards = store.instances_of(0);
    assert_eq!(shards.len(), 2);
    assert_eq!(shards[0], (0, &[9u8, 9][..]), "instances_of must come back in instance order");
    assert_eq!(shards[1], (1, &[4u8][..]));
    assert_eq!(store.bytes(), 5);
}

#[test]
fn merge_shard_frames_pools_statistics_and_drops_meta() {
    // three shards over disjoint seeded streams vs folding their deltas
    // directly — merge_shard_frames must produce the same pooled moments
    let shards: Vec<StandardScaler> =
        (0..3u64).map(|k| scaler(900 + k, 200 + 50 * k as usize)).collect();
    let frames: Vec<Vec<u8>> = shards
        .iter()
        .enumerate()
        .map(|(k, s)| encode_frame(&[(0, s.delta()), (TAG_META_BASE, vec![k as f64])]))
        .collect();
    let mut expect = scaler(900, 200);
    expect.merge(&shards[1]);
    expect.merge(&shards[2]);

    let mut fresh = StandardScaler::new();
    fresh.bind(&schema());
    let mut scratch = Pipeline::new().then(fresh);
    let frame_refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
    let merged = merge_shard_frames(&frame_refs, &mut scratch).unwrap();
    let sections = decode_frame(&merged).unwrap();
    assert!(
        section(&sections, TAG_META_BASE).is_none(),
        "per-shard meta counters must not survive a rescale"
    );
    let got = section(&sections, 0).unwrap();
    assert!(
        payloads_close(got, &expect.delta(), 1e-9),
        "merged frame does not match the directly pooled statistics"
    );

    // the merged frame replicates to any number of new shards exactly
    let mut new_shard = StandardScaler::new();
    new_shard.bind(&schema());
    new_shard.apply_delta(got);
    assert_eq!(bits(&new_shard.delta()), bits(got));

    // a shard frame missing its stage section is a hard error
    let mut fresh = StandardScaler::new();
    fresh.bind(&schema());
    let mut scratch = Pipeline::new().then(fresh);
    let bad = encode_frame(&[(TAG_META_BASE, vec![1.0])]);
    assert!(merge_shard_frames(&[&bad], &mut scratch).is_err());
    assert!(merge_shard_frames(&[], &mut scratch).is_err(), "empty merge set must be rejected");
}
