//! Property-style tests for the sketch guarantees backing the preprocess
//! subsystem (hand-rolled seed loops, like `engine_properties.rs` — no
//! proptest crate offline).
//!
//! * CountMin: overestimate-only, with additive error bounded by εN at the
//!   chosen width/depth.
//! * Misra-Gries: every item with frequency > N/k is recovered, estimates
//!   lower-bound true counts by at most N/k.

use std::collections::HashMap;

use samoa::common::zipf::Zipf;
use samoa::common::Rng;
use samoa::preprocess::{CountMinSketch, MisraGries};

/// Zipf-distributed item stream with its exact counts.
fn zipf_stream(seed: u64, universe: usize, n: usize, theta: f64) -> (Vec<u64>, HashMap<u64, u64>) {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(universe, theta);
    let mut items = Vec::with_capacity(n);
    let mut truth: HashMap<u64, u64> = HashMap::new();
    for _ in 0..n {
        let x = zipf.sample(&mut rng) as u64;
        *truth.entry(x).or_insert(0) += 1;
        items.push(x);
    }
    (items, truth)
}

#[test]
fn prop_countmin_overestimates_within_epsilon_n() {
    // width 1024 ⇒ expected collision mass N/1024 per row; the min over 8
    // rows exceeding 4·N/width is vanishingly unlikely for every tested
    // seed/item (Markov per row: P ≤ 1/4, rows independent ⇒ ≤ 4^-8).
    for seed in 0..8u64 {
        let (items, truth) = zipf_stream(seed, 2000, 20_000, 1.2);
        let mut cm = CountMinSketch::new(1024, 8);
        for &x in &items {
            cm.add(x, 1);
        }
        assert_eq!(cm.total(), items.len() as u64, "seed {seed}");
        let bound = 4 * cm.total() / 1024;
        for (&x, &t) in &truth {
            let est = cm.estimate(x);
            assert!(est >= t, "seed {seed}: item {x} underestimated ({est} < {t})");
            assert!(
                est - t <= bound,
                "seed {seed}: item {x} error {} exceeds εN = {bound}",
                est - t
            );
        }
    }
}

#[test]
fn prop_countmin_weighted_adds() {
    // weighted adds obey the same overestimate-only invariant
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let mut cm = CountMinSketch::new(256, 6);
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for _ in 0..2000 {
            let x = rng.below(300) as u64;
            let w = 1 + rng.below(9) as u64;
            *truth.entry(x).or_insert(0) += w;
            cm.add(x, w);
        }
        for (&x, &t) in &truth {
            assert!(cm.estimate(x) >= t, "seed {seed}");
        }
    }
}

#[test]
fn prop_misra_gries_recovers_heavy_hitters() {
    for seed in 0..8u64 {
        let k = 16 + (seed as usize % 3) * 8; // 16, 24, 32
        let (items, truth) = zipf_stream(seed, 500, 30_000, 1.5);
        let mut mg = MisraGries::new(k);
        for &x in &items {
            mg.add(x);
        }
        let n = mg.total();
        assert_eq!(n, items.len() as u64, "seed {seed}");
        let threshold = n / k as u64;
        for (&x, &t) in &truth {
            let est = mg.estimate(x);
            // estimates never exceed the true count...
            assert!(est <= t, "seed {seed}: item {x} overestimated ({est} > {t})");
            // ...and undershoot by at most N/k
            assert!(
                est + threshold >= t,
                "seed {seed}: item {x} est {est} below {t} - N/k"
            );
            // the defining guarantee: frequency > N/k ⇒ recovered
            if t > threshold {
                assert!(mg.contains(x), "seed {seed}: heavy item {x} (count {t}) lost");
            }
        }
        // summary stays bounded
        assert!(mg.heavy_hitters().len() <= k, "seed {seed}");
    }
}

#[test]
fn prop_misra_gries_ranking_matches_truth_on_skewed_stream() {
    // on a heavily skewed stream the top-3 by MG estimate are the true
    // top-3 (their gaps exceed the N/k error)
    for seed in 0..5u64 {
        let (items, truth) = zipf_stream(seed, 200, 50_000, 2.0);
        let mut mg = MisraGries::new(64);
        for &x in &items {
            mg.add(x);
        }
        let mut true_top: Vec<(u64, u64)> = truth.iter().map(|(&i, &c)| (i, c)).collect();
        true_top.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let hh = mg.heavy_hitters();
        for rank in 0..3 {
            assert_eq!(
                hh[rank].0, true_top[rank].0,
                "seed {seed}: rank {rank} mismatch (mg {hh:?} vs truth {true_top:?})"
            );
        }
    }
}
