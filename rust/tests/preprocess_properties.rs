//! Property-style tests for the sketch guarantees backing the preprocess
//! subsystem (hand-rolled seed loops, like `engine_properties.rs` — no
//! proptest crate offline).
//!
//! * CountMin: overestimate-only, with additive error bounded by εN at the
//!   chosen width/depth.
//! * Misra-Gries: every item with frequency > N/k is recovered, estimates
//!   lower-bound true counts by at most N/k.

use std::collections::HashMap;

use samoa::common::zipf::Zipf;
use samoa::common::Rng;
use samoa::preprocess::{CountMinSketch, MisraGries};

/// Zipf-distributed item stream with its exact counts.
fn zipf_stream(seed: u64, universe: usize, n: usize, theta: f64) -> (Vec<u64>, HashMap<u64, u64>) {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(universe, theta);
    let mut items = Vec::with_capacity(n);
    let mut truth: HashMap<u64, u64> = HashMap::new();
    for _ in 0..n {
        let x = zipf.sample(&mut rng) as u64;
        *truth.entry(x).or_insert(0) += 1;
        items.push(x);
    }
    (items, truth)
}

#[test]
fn prop_countmin_overestimates_within_epsilon_n() {
    // width 1024 ⇒ expected collision mass N/1024 per row; the min over 8
    // rows exceeding 4·N/width is vanishingly unlikely for every tested
    // seed/item (Markov per row: P ≤ 1/4, rows independent ⇒ ≤ 4^-8).
    for seed in 0..8u64 {
        let (items, truth) = zipf_stream(seed, 2000, 20_000, 1.2);
        let mut cm = CountMinSketch::new(1024, 8);
        for &x in &items {
            cm.add(x, 1);
        }
        assert_eq!(cm.total(), items.len() as u64, "seed {seed}");
        let bound = 4 * cm.total() / 1024;
        for (&x, &t) in &truth {
            let est = cm.estimate(x);
            assert!(est >= t, "seed {seed}: item {x} underestimated ({est} < {t})");
            assert!(
                est - t <= bound,
                "seed {seed}: item {x} error {} exceeds εN = {bound}",
                est - t
            );
        }
    }
}

#[test]
fn prop_countmin_weighted_adds() {
    // weighted adds obey the same overestimate-only invariant
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let mut cm = CountMinSketch::new(256, 6);
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for _ in 0..2000 {
            let x = rng.below(300) as u64;
            let w = 1 + rng.below(9) as u64;
            *truth.entry(x).or_insert(0) += w;
            cm.add(x, w);
        }
        for (&x, &t) in &truth {
            assert!(cm.estimate(x) >= t, "seed {seed}");
        }
    }
}

#[test]
fn prop_misra_gries_recovers_heavy_hitters() {
    for seed in 0..8u64 {
        let k = 16 + (seed as usize % 3) * 8; // 16, 24, 32
        let (items, truth) = zipf_stream(seed, 500, 30_000, 1.5);
        let mut mg = MisraGries::new(k);
        for &x in &items {
            mg.add(x);
        }
        let n = mg.total();
        assert_eq!(n, items.len() as u64, "seed {seed}");
        let threshold = n / k as u64;
        for (&x, &t) in &truth {
            let est = mg.estimate(x);
            // estimates never exceed the true count...
            assert!(est <= t, "seed {seed}: item {x} overestimated ({est} > {t})");
            // ...and undershoot by at most N/k
            assert!(
                est + threshold >= t,
                "seed {seed}: item {x} est {est} below {t} - N/k"
            );
            // the defining guarantee: frequency > N/k ⇒ recovered
            if t > threshold {
                assert!(mg.contains(x), "seed {seed}: heavy item {x} (count {t}) lost");
            }
        }
        // summary stays bounded
        assert!(mg.heavy_hitters().len() <= k, "seed {seed}");
    }
}

/// Reference reimplementation of the pre-Fenwick `Discretizer` layer-1
/// summary (exact buffer → equal-width freeze with 10% pad → clamped
/// cells → linear-scan rank with in-cell interpolation), used to pin bin
/// assignments across the prefix-sum caching rewrite.
struct ReferenceDiscretizer {
    k: u32,
    warmup: usize,
    fine: usize,
    buffer: Vec<f32>,
    counts: Vec<f64>,
    lo: f64,
    hi: f64,
    n: f64,
}

impl ReferenceDiscretizer {
    fn new(k: u32, warmup: usize, fine: usize) -> Self {
        ReferenceDiscretizer {
            k,
            warmup,
            fine,
            buffer: Vec::new(),
            counts: Vec::new(),
            lo: 0.0,
            hi: 0.0,
            n: 0.0,
        }
    }

    fn cell(&self, x: f64) -> usize {
        let fine = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        ((t * fine as f64) as isize).clamp(0, fine as isize - 1) as usize
    }

    fn add_then_bin(&mut self, x: f64) -> u32 {
        self.n += 1.0;
        if self.counts.is_empty() {
            self.buffer.push(x as f32);
            if self.buffer.len() >= self.warmup {
                let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
                for &v in &self.buffer {
                    lo = lo.min(v as f64);
                    hi = hi.max(v as f64);
                }
                let pad = (hi - lo).max(1e-9) * 0.1;
                self.lo = lo - pad;
                self.hi = hi + pad;
                self.counts = vec![0.0; self.fine];
                let buffer = std::mem::take(&mut self.buffer);
                for &v in &buffer {
                    let c = self.cell(v as f64);
                    self.counts[c] += 1.0;
                }
            }
        } else {
            let c = self.cell(x);
            self.counts[c] += 1.0;
        }
        let rank = if self.counts.is_empty() {
            let below = self.buffer.iter().filter(|&&v| (v as f64) < x).count();
            below as f64 / self.buffer.len() as f64
        } else {
            let c = self.cell(x);
            let below: f64 = self.counts[..c].iter().sum();
            let cell_lo = self.lo + (self.hi - self.lo) * c as f64 / self.counts.len() as f64;
            let cell_w = (self.hi - self.lo) / self.counts.len() as f64;
            let frac = ((x - cell_lo) / cell_w).clamp(0.0, 1.0);
            (below + frac * self.counts[c]) / self.n
        };
        ((rank * self.k as f64) as u32).min(self.k - 1)
    }
}

/// Regression pin: the Fenwick-backed `Discretizer` must emit bit-
/// identical bin assignments to the pre-rewrite linear-scan algorithm on
/// seeded streams (several k / resolution / distribution combinations).
#[test]
fn prop_discretizer_bins_pinned_across_prefix_sum_rewrite() {
    use samoa::core::instance::{Instance, Label};
    use samoa::core::Schema;
    use samoa::preprocess::{Discretizer, Transform};

    for (seed, k, warmup, fine) in
        [(1u64, 4u32, 32usize, 64usize), (2, 8, 256, 128), (3, 6, 64, 96)]
    {
        let schema = Schema::classification("t", Schema::all_numeric(1), 2);
        let mut d = Discretizer::with_resolution(k, warmup, fine);
        d.bind(&schema);
        let mut reference = ReferenceDiscretizer::new(k, warmup, fine);
        let mut rng = Rng::new(seed);
        for i in 0..6000 {
            let x = match i % 3 {
                0 => rng.gaussian() * 3.0,
                1 => rng.f64() * 20.0 - 5.0,
                _ => rng.gaussian() * 0.5 + 8.0,
            };
            let out = d.transform(Instance::dense(vec![x as f32], Label::None)).unwrap();
            let want = reference.add_then_bin(x as f32 as f64);
            assert_eq!(
                out.value(0) as u32,
                want,
                "seed {seed}, instance {i}: bin diverged from the pre-rewrite algorithm"
            );
        }
    }
}

#[test]
fn prop_misra_gries_ranking_matches_truth_on_skewed_stream() {
    // on a heavily skewed stream the top-3 by MG estimate are the true
    // top-3 (their gaps exceed the N/k error)
    for seed in 0..5u64 {
        let (items, truth) = zipf_stream(seed, 200, 50_000, 2.0);
        let mut mg = MisraGries::new(64);
        for &x in &items {
            mg.add(x);
        }
        let mut true_top: Vec<(u64, u64)> = truth.iter().map(|(&i, &c)| (i, c)).collect();
        true_top.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let hh = mg.heavy_hitters();
        for rank in 0..3 {
            assert_eq!(
                hh[rank].0, true_top[rank].0,
                "seed {seed}: rank {rank} mismatch (mg {hh:?} vs truth {true_top:?})"
            );
        }
    }
}
