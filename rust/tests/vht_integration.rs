//! End-to-end VHT: full topology (source → MA → LS → MA → evaluator) on
//! both the local and the threaded engine, across wok / wk(z) / delay
//! configurations. Checks the paper's qualitative claims: VHT-local
//! matches the sequential tree, distributed variants stay close, state is
//! dropped after splits.

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree, LeafPrediction};
use samoa::classifiers::vht::{build_topology, SplitBuffering, VhtConfig};
use samoa::core::model::Classifier;
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::streams::{random_tree::RandomTreeGenerator, StreamSource};
use samoa::topology::Event;

fn run_vht_local(config: &VhtConfig, n: u64, seed: u64) -> (f64, samoa::engine::EngineMetrics) {
    let mut stream = RandomTreeGenerator::new(5, 5, 2, seed);
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, 100_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(stream.schema(), config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source = (0..n).map(move |id| Event::Instance {
        id,
        inst: stream.next_instance().unwrap(),
    });
    let metrics = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    (sink.accuracy(), metrics)
}

#[test]
fn vht_local_matches_sequential_tree() {
    // VHT with zero feedback delay == sequential Hoeffding tree (both
    // majority-class prediction) to within statistical noise
    let config = VhtConfig {
        parallelism: 2,
        feedback_delay: 0,
        buffering: SplitBuffering::Discard,
        ..Default::default()
    };
    let (vht_acc, metrics) = run_vht_local(&config, 30_000, 7);

    let mut stream = RandomTreeGenerator::new(5, 5, 2, 7);
    let mut ht = HoeffdingTree::new(
        stream.schema().clone(),
        HTConfig { leaf_prediction: LeafPrediction::MajorityClass, ..Default::default() },
    );
    let mut correct = 0u64;
    for _ in 0..30_000 {
        let inst = stream.next_instance().unwrap();
        if ht.predict(&inst) == inst.class() {
            correct += 1;
        }
        ht.train(&inst);
    }
    let ht_acc = correct as f64 / 30_000.0;

    assert!(
        (vht_acc - ht_acc).abs() < 0.05,
        "VHT local {vht_acc:.3} vs sequential {ht_acc:.3}"
    );
    assert!(vht_acc > 0.6, "vht_acc={vht_acc}");
    // messages flowed on every VHT stream
    assert!(metrics.streams[1].events > 0, "no attribute events");
    assert!(metrics.streams[2].events > 0, "no compute events");
    assert!(metrics.streams[3].events > 0, "no local-result events");
}

#[test]
fn feedback_delay_degrades_accuracy_gracefully() {
    // wok with a large feedback delay must lose some accuracy vs local
    // but still learn (paper: within 18% of local)
    let base = VhtConfig { parallelism: 2, ..Default::default() };
    let delayed = VhtConfig { parallelism: 2, feedback_delay: 500, ..Default::default() };
    let (acc_local, _) = run_vht_local(&base, 30_000, 11);
    let (acc_delay, _) = run_vht_local(&delayed, 30_000, 11);
    assert!(acc_delay > 0.55, "delayed VHT stopped learning: {acc_delay}");
    assert!(
        acc_local >= acc_delay - 0.02,
        "delay should not help: local={acc_local} delayed={acc_delay}"
    );
}

#[test]
fn buffering_replays_instances() {
    let config = VhtConfig {
        parallelism: 2,
        feedback_delay: 200,
        buffering: SplitBuffering::Buffer(1000),
        ..Default::default()
    };
    let (acc, _) = run_vht_local(&config, 30_000, 13);
    assert!(acc > 0.55, "wk(z) accuracy {acc}");
}

#[test]
fn threaded_engine_runs_vht() {
    let config = VhtConfig { parallelism: 4, ..Default::default() };
    let mut stream = RandomTreeGenerator::new(5, 5, 2, 17);
    let sink = EvalSink::new(stream.schema().n_classes(), 1.0, 100_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source = (0..20_000u64).map(move |id| Event::Instance {
        id,
        inst: stream.next_instance().unwrap(),
    });
    let metrics = ThreadedEngine::default().run(&topo, handles.entry, source, |_, _, _| {});
    assert_eq!(metrics.source_instances, 20_000);
    let acc = sink.accuracy();
    // asynchronous split decisions: accuracy lower than local but learning
    assert!(acc > 0.55, "threaded VHT accuracy {acc}");
}

#[test]
fn sparse_vht_learns_tweets() {
    let config = VhtConfig {
        parallelism: 2,
        sparse: true,
        grace_period: 500,
        ..Default::default()
    };
    let mut stream = samoa::streams::random_tweet::RandomTweetGenerator::new(100, 3);
    let sink = EvalSink::new(2, 1.0, 100_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_topology(stream.schema(), &config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source = (0..40_000u64).map(move |id| Event::Instance {
        id,
        inst: stream.next_instance().unwrap(),
    });
    LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    let acc = sink.accuracy();
    assert!(acc > 0.6, "sparse VHT accuracy {acc} (chance = 0.5)");
}
