//! Golden-equivalence suite for the zero-copy data plane: the Arc-shared
//! instance/event refactor and the threaded micro-batching must be
//! *semantically invisible*. Pinned here:
//!
//! * VHT (the fig8/9 harness shape), dense and sparse: the batched
//!   (`AttributeBatch`, Arc payload) and unbatched (per-`Attribute`)
//!   decompositions produce **bit-identical** accuracy, kappa and split
//!   decisions on the local engine — and identical reruns stay
//!   bit-identical (stream events *and* bytes), so any change to event
//!   payloads or routing shows up as a diff here;
//! * AMRules (VAMR topology) and CluStream harnesses: bit-identical
//!   reruns on the local engine, quality within sane floors;
//! * threaded engine micro-batching: no event loss and no reordering
//!   within a (sender, dest-instance) edge at any batch size, for both
//!   key-grouped and broadcast fan-out, under tiny-queue backpressure.

mod common;

use std::sync::{Arc, Mutex};
use std::time::Duration;

use common::{Fwd, Recorder};

use samoa::classifiers::vht::{build_topology as build_vht, ModelAggregator, VhtConfig};
use samoa::clustering::clustream::CluStreamConfig;
use samoa::common::Rng;
use samoa::core::instance::{Instance, Label};
use samoa::engine::{EngineMetrics, LocalEngine, ThreadedEngine};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::regressors::amrules::AMRulesConfig;
use samoa::streams::{random_tree::RandomTreeGenerator, StreamSource};
use samoa::topology::{Event, Grouping, StreamId, TopologyBuilder};

/// Everything a VHT run can disagree on: quality, split decisions, and
/// the full per-stream traffic signature.
#[derive(Debug, PartialEq)]
struct VhtFingerprint {
    accuracy_bits: u64,
    kappa_bits: u64,
    splits: u64,
    split_rounds: u64,
    stream_events: Vec<u64>,
    stream_bytes: Vec<u64>,
}

fn fingerprint(sink: &EvalSink, splits: (u64, u64), m: &EngineMetrics) -> VhtFingerprint {
    VhtFingerprint {
        accuracy_bits: sink.accuracy().to_bits(),
        kappa_bits: sink.classification.lock().unwrap().kappa().to_bits(),
        splits: splits.0,
        split_rounds: splits.1,
        stream_events: m.streams.iter().map(|s| s.events).collect(),
        stream_bytes: m.streams.iter().map(|s| s.bytes).collect(),
    }
}

/// Run the VHT harness (local engine) and fingerprint the result.
fn run_vht(config: &VhtConfig, sparse: bool, n: u64, seed: u64) -> VhtFingerprint {
    let mut stream: Box<dyn StreamSource> = if sparse {
        Box::new(samoa::streams::random_tweet::RandomTweetGenerator::new(100, seed))
    } else {
        Box::new(RandomTreeGenerator::new(5, 5, 2, seed))
    };
    let schema = stream.schema().clone();
    let sink = EvalSink::new(schema.n_classes(), 1.0, n);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) = build_vht(&schema, config, move |_| {
        Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
    });
    let source =
        (0..n).map_while(|id| stream.next_instance().map(|inst| Event::Instance { id, inst }));
    let mut splits = (0, 0);
    let m = LocalEngine::new().run(&topo, handles.entry, source, |instances| {
        if let Some(ma) = instances[handles.ma.0][0]
            .as_any()
            .and_then(|a| a.downcast_ref::<ModelAggregator>())
        {
            splits = (ma.stats.splits, ma.stats.split_rounds);
        }
    });
    assert_eq!(m.source_instances, n);
    fingerprint(&sink, splits, &m)
}

/// Dense VHT: the Arc-batched attribute path must be bit-identical to the
/// per-attribute path — same accuracy, same kappa, same splits, and (up
/// to the per-event framing difference) the same decisions at every leaf.
#[test]
fn vht_dense_batched_equals_unbatched() {
    let base = VhtConfig { parallelism: 2, ..Default::default() };
    let batched = run_vht(&VhtConfig { batch_attributes: true, ..base.clone() }, false, 20_000, 7);
    let unbatched =
        run_vht(&VhtConfig { batch_attributes: false, ..base.clone() }, false, 20_000, 7);
    assert_eq!(batched.accuracy_bits, unbatched.accuracy_bits, "accuracy must be bit-identical");
    assert_eq!(batched.kappa_bits, unbatched.kappa_bits, "kappa must be bit-identical");
    assert_eq!(
        (batched.splits, batched.split_rounds),
        (unbatched.splits, unbatched.split_rounds),
        "split decisions must be identical"
    );
    // sanity floor so a silently-broken pipeline can't pass as "equal"
    assert!(f64::from_bits(batched.accuracy_bits) > 0.6);
    assert!(batched.splits > 0, "harness never split — test is vacuous");
}

/// Sparse VHT (random tweets): same contract as the dense case.
#[test]
fn vht_sparse_batched_equals_unbatched() {
    let base = VhtConfig { parallelism: 2, sparse: true, grace_period: 500, ..Default::default() };
    let batched = run_vht(&VhtConfig { batch_attributes: true, ..base.clone() }, true, 20_000, 3);
    let unbatched =
        run_vht(&VhtConfig { batch_attributes: false, ..base.clone() }, true, 20_000, 3);
    assert_eq!(batched.accuracy_bits, unbatched.accuracy_bits);
    assert_eq!(batched.kappa_bits, unbatched.kappa_bits);
    assert_eq!(
        (batched.splits, batched.split_rounds),
        (unbatched.splits, unbatched.split_rounds)
    );
    assert!(f64::from_bits(batched.accuracy_bits) > 0.55);
}

/// Reruns of the same VHT configuration are bit-identical end to end —
/// including wire bytes, so payload-size accounting changes are caught.
#[test]
fn vht_rerun_bit_identical() {
    let config = VhtConfig { parallelism: 4, ..Default::default() };
    let a = run_vht(&config, false, 15_000, 11);
    let b = run_vht(&config, false, 15_000, 11);
    assert_eq!(a, b);
}

/// AMRules via the VAMR topology: bit-identical reruns on the local
/// engine (covers `RuleInstance` / `NewRule` / `RuleFeature` / `RuleHead`
/// Arc payloads), MAE within a sane ceiling.
#[test]
fn amrules_topology_rerun_bit_identical() {
    let run = || {
        let schema =
            samoa::core::Schema::regression("pw", samoa::core::Schema::all_numeric(2), -12.0, 12.0);
        let sink = EvalSink::new(0, schema.label_range(), 100_000);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = samoa::regressors::vamr::build_topology(
            &schema,
            &AMRulesConfig::default(),
            2,
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let mut rng = Rng::new(5);
        let source = (0..15_000u64).map(move |id| {
            let x0 = rng.f32();
            let y = if x0 <= 0.5 { 10.0 } else { -10.0 } + 0.2 * rng.gaussian();
            Event::Instance { id, inst: Instance::dense(vec![x0, rng.f32()], Label::Numeric(y)) }
        });
        let m = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
        let events: Vec<u64> = m.streams.iter().map(|s| s.events).collect();
        let bytes: Vec<u64> = m.streams.iter().map(|s| s.bytes).collect();
        (sink.mae().to_bits(), events, bytes)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert!(f64::from_bits(a.0) < 4.0, "MAE {} suspicious for ±10 target", f64::from_bits(a.0));
}

/// CluStream harness: bit-identical reruns (covers `ClusterAssign`
/// instances and Arc'd `CentroidSnapshot` broadcasts).
#[test]
fn clustream_topology_rerun_bit_identical() {
    let run = || {
        let schema = samoa::core::Schema::classification(
            "b",
            samoa::core::Schema::all_numeric(4),
            2,
        );
        let config = CluStreamConfig {
            max_micro: 30,
            k: 3,
            macro_period: 100_000,
            ..Default::default()
        };
        let (topo, handles) =
            samoa::clustering::topology::build_topology(&schema, config, 3, 5, 500);
        let mut rng = Rng::new(1);
        let source = (0..6_000u64).map(move |id| {
            let c = [0.0f32, 5.0, 10.0][(id % 3) as usize];
            let vals: Vec<f32> = (0..4).map(|_| c + 0.2 * rng.gaussian() as f32).collect();
            Event::Instance { id, inst: Instance::dense(vals, Label::None) }
        });
        let mut state = 0usize;
        let m = LocalEngine::new().run(&topo, handles.entry, source, |instances| {
            state = instances[handles.aggregator.0][0].mem_bytes();
        });
        let events: Vec<u64> = m.streams.iter().map(|s| s.events).collect();
        let bytes: Vec<u64> = m.streams.iter().map(|s| s.bytes).collect();
        (state, events, bytes)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert!(a.0 > 0, "aggregator built no state");
}

// ---------------------------------------------------------------------
// Threaded-engine micro-batching: loss/ordering contract
// (probe topology + Fwd/Recorder shared with engine_properties via
// tests/common — see common::run_edge_probe)
// ---------------------------------------------------------------------

/// Run source → fwd(p=1) → recorder(p) on `eng` (no consumer spin) and
/// return the per-instance logs.
fn run_edge_probe(grouping: Grouping, p: usize, n: u64, eng: ThreadedEngine) -> Vec<Vec<u64>> {
    common::run_edge_probe(grouping, p, n, Duration::ZERO, eng).1
}

/// Key-grouped edge: at every batch size (1 = unbatched baseline,
/// oversized = one flush), no event is lost, none duplicated, and each
/// (sender, dest-instance) edge preserves emission order — under
/// tiny-queue backpressure too.
#[test]
fn threaded_batching_key_grouped_no_loss_no_reorder() {
    const N: u64 = 5_000;
    for batch in [1usize, 7, 32, 1024] {
        let logs = run_edge_probe(Grouping::Key, 3, N, ThreadedEngine::new(4).with_batch(batch));
        let total: usize = logs.iter().map(|l| l.len()).sum();
        assert_eq!(total, N as usize, "batch={batch}: lost/duplicated events");
        let mut seen: Vec<u64> = logs.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert!(seen.windows(2).all(|w| w[0] < w[1]), "batch={batch}: duplicates");
        for (i, l) in logs.iter().enumerate() {
            assert!(
                l.windows(2).all(|w| w[0] < w[1]),
                "batch={batch}: edge to instance {i} reordered"
            );
        }
    }
}

/// Broadcast edge: every destination instance sees EVERY event exactly
/// once, in order, at every batch size.
#[test]
fn threaded_batching_broadcast_no_loss_no_reorder() {
    const N: u64 = 3_000;
    for batch in [1usize, 32, 4096] {
        let logs = run_edge_probe(Grouping::All, 4, N, ThreadedEngine::new(4).with_batch(batch));
        for (i, l) in logs.iter().enumerate() {
            assert_eq!(l.len(), N as usize, "batch={batch}: instance {i} missed events");
            assert!(
                l.windows(2).all(|w| w[0] < w[1]),
                "batch={batch}: edge to instance {i} reordered"
            );
        }
    }
}

/// Flow-control configuration is semantically invisible: the exact
/// per-edge delivery sequences are bit-identical across bounded vs
/// unbounded channels, fixed vs adaptive batching, and pinned vs
/// work-stealing scheduling — for key-grouped and broadcast fan-out.
#[test]
fn edge_sequences_identical_across_flow_control_configs() {
    const N: u64 = 4_000;
    for (gname, grouping) in [("key", Grouping::Key), ("broadcast", Grouping::All)] {
        let baseline = run_edge_probe(grouping, 3, N, ThreadedEngine::new(4).with_batch(7));
        let configs: Vec<(&str, ThreadedEngine)> = vec![
            ("unbounded fixed", ThreadedEngine::default().unbounded().with_batch(7)),
            ("bounded adaptive", ThreadedEngine::new(4).with_adaptive_batch(32)),
            ("steal bounded", ThreadedEngine::new(4).with_batch(7).with_workers(2)),
            (
                "steal adaptive unbounded",
                ThreadedEngine::default().unbounded().with_workers(2),
            ),
        ];
        for (name, eng) in configs {
            let logs = run_edge_probe(grouping, 3, N, eng);
            assert_eq!(logs, baseline, "{gname}: '{name}' diverged from baseline");
        }
    }
}

/// The batched threaded engine reaches the same totals as the local
/// engine on the same topology (conservation across engines).
#[test]
fn threaded_totals_match_local() {
    let build = || {
        let mut b = TopologyBuilder::new("x");
        let fwd = b.add_processor("fwd", 1, |_| Box::new(Fwd(StreamId(1))));
        let rec = b.add_processor("rec", 4, |_| {
            Box::new(Recorder {
                log: Arc::new(Mutex::new(vec![Vec::new(); 4])),
                spin: Duration::ZERO,
            })
        });
        let entry = b.stream("in", None, fwd, Grouping::Shuffle);
        b.stream("edge", Some(fwd), rec, Grouping::All);
        (b.build(), entry)
    };
    let source = || {
        (0..2_000u64)
            .map(|id| Event::Instance { id, inst: Instance::dense(vec![0.0], Label::None) })
    };
    let (t1, e1) = build();
    let local = LocalEngine::new().run(&t1, e1, source(), |_| {});
    let engines: Vec<(&str, ThreadedEngine)> = vec![
        ("default", ThreadedEngine::default()),
        ("tiny bounded", ThreadedEngine::new(2).with_batch(4)),
        ("steal", ThreadedEngine::default().with_workers(2)),
    ];
    for (name, eng) in engines {
        let (t2, e2) = build();
        let threaded = eng.run(&t2, e2, source(), |_, _, _| {});
        for s in 0..local.streams.len() {
            assert_eq!(
                local.streams[s].events, threaded.streams[s].events,
                "{name}: stream {s} events"
            );
            assert_eq!(
                local.streams[s].bytes, threaded.streams[s].bytes,
                "{name}: stream {s} bytes"
            );
        }
    }
}
