//! Property tests of the event wire codec (`topology::codec`): every
//! `Event` variant round-trips bit-exactly (dense and sparse payloads,
//! empty batches, control events), and truncated or corrupt frames are
//! rejected with an error — never a panic, never a wrong decode.

use std::sync::Arc;

use samoa::core::instance::{Instance, Label};
use samoa::regressors::rule::{Feature, HeadSnapshot, Op, RuleSpec};
use samoa::topology::codec::{
    decode_event, decode_peer_frame, decode_peer_sched, encode_event_vec, encode_peer_frame,
    encode_peer_sched, FRAME_PEER, FRAME_PEER_SCHED,
};
use samoa::topology::{Event, Output};

/// One exemplar per `Event` variant, exercising dense + sparse instance
/// payloads, weighted instances, empty vectors, extreme ids and every
/// enum discriminant reachable from the event graph.
fn exemplars() -> Vec<Event> {
    let mut weighted = Instance::sparse(
        vec![0, 7, 4095],
        vec![1.0, -0.5, 33.25],
        8192,
        Label::Numeric(-2.5),
    );
    weighted.weight = 2.5;
    vec![
        // generic
        Event::Instance { id: 0, inst: Instance::dense(vec![], Label::None) },
        Event::Instance {
            id: 1,
            inst: Instance::dense(vec![0.5, -1.25, 3.75], Label::Class(2)),
        },
        Event::Instance { id: u64::MAX, inst: weighted },
        Event::Prediction { id: 9, truth: Label::Class(1), output: Output::Class(0) },
        Event::Prediction { id: 10, truth: Label::Numeric(0.125), output: Output::Numeric(-0.25) },
        Event::Prediction { id: 11, truth: Label::None, output: Output::None },
        Event::Shutdown,
        // preprocess delta-sync
        Event::StatsDelta { stage: 0, shard: 3, round: 17, payload: Arc::new(vec![1.5, -2.5]) },
        Event::StatsDelta { stage: 2, shard: 0, round: 0, payload: Arc::new(vec![]) },
        Event::StatsGlobal { stage: 1, payload: Arc::new(vec![0.0, f64::MAX, f64::MIN]) },
        // VHT
        Event::Attribute { leaf: 5, attr: 2, value: 1.5, class: 1, weight: 1.0 },
        Event::AttributeBatch {
            leaf: 6,
            class: 0,
            weight: 0.5,
            attrs: Arc::new(vec![(0, 1), (3, 0), (255, 7)]),
        },
        Event::AttributeBatch { leaf: 7, class: 2, weight: 1.0, attrs: Arc::new(vec![]) },
        Event::Compute { leaf: 8, seq: 3, n_l: 120.0, class_counts: Arc::new(vec![50.0, 70.0]) },
        Event::Compute { leaf: 9, seq: 4, n_l: 0.0, class_counts: Arc::new(vec![]) },
        Event::LocalResult {
            leaf: 10,
            seq: 5,
            best_attr: 1,
            best: 0.75,
            second_attr: 0,
            second: 0.5,
            best_dist: Arc::new(vec![1.0, 2.0, 3.0, 4.0]),
        },
        Event::DropLeaf { leaf: u64::MAX },
        // AMRules
        Event::RuleInstance {
            rule: 3,
            inst: Instance::dense(vec![9.0, -9.0], Label::Numeric(4.5)),
        },
        Event::NewRule {
            rule: 4,
            spec: Arc::new(RuleSpec {
                features: vec![
                    Feature { attr: 0, op: Op::Le, threshold: 1.5 },
                    Feature { attr: 3, op: Op::Gt, threshold: -0.5 },
                    Feature { attr: 7, op: Op::Eq, threshold: 2.0 },
                ],
                head: HeadSnapshot { mean: 0.25, weights: Some(vec![0.1, 0.2, 0.3]) },
            }),
        },
        Event::NewRule {
            rule: 5,
            spec: Arc::new(RuleSpec {
                features: vec![],
                head: HeadSnapshot { mean: -1.0, weights: None },
            }),
        },
        Event::RuleFeature {
            rule: 6,
            feature: Feature { attr: 2, op: Op::Gt, threshold: 0.0 },
            head: Arc::new(HeadSnapshot { mean: 2.5, weights: Some(vec![]) }),
        },
        Event::RuleHead {
            rule: 7,
            head: Arc::new(HeadSnapshot { mean: 0.0, weights: None }),
        },
        Event::RuleRemoved { rule: u32::MAX },
        // CluStream
        Event::ClusterAssign {
            idx: 2,
            dist2: 0.0625,
            inst: Instance::dense(vec![1.0, 2.0], Label::None),
        },
        Event::CentroidSnapshot {
            version: 12,
            k: 2,
            d: 3,
            centers: Arc::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            weights: Arc::new(vec![10.0, 20.0]),
        },
        Event::CentroidSnapshot {
            version: 0,
            k: 0,
            d: 0,
            centers: Arc::new(vec![]),
            weights: Arc::new(vec![]),
        },
    ]
}

/// Debug formatting is a faithful structural fingerprint for events with
/// finite float payloads (NaN bit-exactness is asserted separately).
fn fingerprint(e: &Event) -> String {
    format!("{e:?}")
}

#[test]
fn every_variant_roundtrips() {
    // All 17 Event variants must be covered by the exemplar list — if a
    // variant is added to the enum without a codec arm, encode_event
    // fails to compile (exhaustive match), but this guards the *test*
    // against silently losing coverage.
    let evs = exemplars();
    let tags: std::collections::BTreeSet<u8> = evs
        .iter()
        .map(|e| encode_event_vec(e)[0])
        .collect();
    assert_eq!(tags.len(), 17, "exemplars must cover all 17 event tags, got {tags:?}");

    for e in &evs {
        let bytes = encode_event_vec(e);
        let (decoded, used) =
            decode_event(&bytes).unwrap_or_else(|err| panic!("decode {e:?}: {err}"));
        assert_eq!(used, bytes.len(), "whole buffer consumed for {e:?}");
        assert_eq!(fingerprint(e), fingerprint(&decoded));
    }
}

#[test]
fn roundtrip_is_stable_under_reencoding() {
    for e in &exemplars() {
        let b1 = encode_event_vec(e);
        let (d1, _) = decode_event(&b1).unwrap();
        let b2 = encode_event_vec(&d1);
        assert_eq!(b1, b2, "re-encoding must be byte-identical for {e:?}");
    }
}

#[test]
fn nan_payload_bits_survive() {
    // The NaN-tagged sparse stats encoding of preprocess::wire stores
    // tag + mask words as non-canonical NaN bit patterns inside StatsDelta
    // payloads; the codec must carry them through bit-exactly.
    let patterns = [
        0x7FF8_0000_0000_0001u64,
        0x7FF8_DEAD_BEEF_0001,
        0xFFF8_0000_0000_0042,
        f64::NAN.to_bits(),
    ];
    let payload: Vec<f64> = patterns.iter().map(|&b| f64::from_bits(b)).collect();
    let e = Event::StatsDelta { stage: 1, shard: 2, round: 3, payload: Arc::new(payload) };
    let (d, _) = decode_event(&encode_event_vec(&e)).unwrap();
    match d {
        Event::StatsDelta { payload, .. } => {
            let got: Vec<u64> = payload.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, patterns.to_vec());
        }
        other => panic!("wrong variant {other:?}"),
    }
}

#[test]
fn every_truncation_of_every_variant_is_rejected() {
    for e in &exemplars() {
        let bytes = encode_event_vec(e);
        for cut in 0..bytes.len() {
            assert!(
                decode_event(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must fail for {e:?}",
                bytes.len()
            );
        }
    }
}

#[test]
fn corrupt_tags_and_discriminants_are_rejected() {
    assert!(decode_event(&[]).is_err(), "empty buffer");
    assert!(decode_event(&[0]).is_err(), "tag 0 is reserved");
    for tag in 18..=255u8 {
        assert!(decode_event(&[tag]).is_err(), "unknown tag {tag}");
    }
    // Corrupt an inner enum discriminant: Prediction's Label byte.
    let e = Event::Prediction { id: 1, truth: Label::Class(2), output: Output::None };
    let mut bytes = encode_event_vec(&e);
    bytes[9] = 9; // tag(1) + id(8), then the label discriminant
    assert!(decode_event(&bytes).is_err(), "unknown label kind");
}

#[test]
fn oversized_length_prefixes_are_rejected_not_allocated() {
    // A StatsGlobal frame claiming u32::MAX f64 elements in a 9-byte
    // buffer must fail on the validated length, not try to allocate 32 GB.
    let mut bytes = vec![5u8]; // StatsGlobal tag
    bytes.extend_from_slice(&0u32.to_le_bytes()); // stage
    bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // payload len
    assert!(decode_event(&bytes).is_err());

    // Same for a sparse instance claiming an enormous index count.
    let mut bytes = vec![1u8]; // Instance tag
    bytes.extend_from_slice(&7u64.to_le_bytes()); // id
    bytes.push(1); // sparse values kind
    bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // n entries
    assert!(decode_event(&bytes).is_err());
}

#[test]
fn peer_frame_roundtrips_every_variant() {
    // The worker↔worker data plane wraps an arbitrary event in
    // `[FRAME_PEER][lseq][pid][iid][event]`; header fields and payload
    // must survive for every event shape, including extreme values.
    for (i, e) in exemplars().iter().enumerate() {
        let lseq = (i as u64) << 32 | 0xABCD;
        let (pid, iid) = (i as u16, u16::MAX - i as u16);
        let bytes = encode_peer_frame(lseq, pid, iid, e);
        assert_eq!(bytes[0], FRAME_PEER);
        let (l2, p2, i2, e2) =
            decode_peer_frame(&bytes).unwrap_or_else(|err| panic!("decode {e:?}: {err}"));
        assert_eq!((l2, p2, i2), (lseq, pid, iid));
        assert_eq!(fingerprint(e), fingerprint(&e2));
    }
}

#[test]
fn peer_frame_truncation_corruption_and_trailing_bytes_are_rejected() {
    let e = Event::Instance {
        id: 42,
        inst: Instance::dense(vec![1.0, -2.0, 3.0], Label::Class(1)),
    };
    let bytes = encode_peer_frame(7, 1, 2, &e);
    // a peer frame crosses a process boundary: every truncation must
    // error, never panic or decode short
    for cut in 0..bytes.len() {
        assert!(decode_peer_frame(&bytes[..cut]).is_err(), "truncation at {cut} must fail");
    }
    // wrong kind byte (e.g. a coordinator frame misrouted onto the link)
    let mut wrong = bytes.clone();
    wrong[0] = FRAME_PEER_SCHED;
    assert!(decode_peer_frame(&wrong).is_err(), "wrong kind must fail");
    // trailing garbage after the event is a framing bug, not padding
    let mut long = bytes.clone();
    long.push(0);
    assert!(decode_peer_frame(&long).is_err(), "trailing byte must fail");
}

#[test]
fn peer_sched_tokens_roundtrip_in_order() {
    // The deterministic merge depends on token order: the receiver pops
    // its slot map in wseq order, so decode must preserve encode order
    // exactly (including duplicate senders and non-monotonic slots).
    let tokens: Vec<(u64, u8)> =
        vec![(0, 0), (5, 1), (3, 1), (u64::MAX, 255), (4, 0), (4, 2)];
    let bytes = encode_peer_sched(&tokens);
    assert_eq!(bytes[0], FRAME_PEER_SCHED);
    assert_eq!(decode_peer_sched(&bytes).unwrap(), tokens);
    // empty schedule frames are legal (a flush with no pending tokens)
    assert_eq!(decode_peer_sched(&encode_peer_sched(&[])).unwrap(), Vec::<(u64, u8)>::new());
}

#[test]
fn peer_sched_truncation_and_length_lies_are_rejected() {
    let tokens: Vec<(u64, u8)> = (0..4u64).map(|s| (s, s as u8)).collect();
    let bytes = encode_peer_sched(&tokens);
    for cut in 0..bytes.len() {
        assert!(decode_peer_sched(&bytes[..cut]).is_err(), "truncation at {cut} must fail");
    }
    // a count claiming more tokens than the buffer holds must fail on
    // the validated length, not allocate or read past the end
    let mut lie = bytes.clone();
    lie[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode_peer_sched(&lie).is_err(), "oversized token count must fail");
    let mut wrong = bytes.clone();
    wrong[0] = FRAME_PEER;
    assert!(decode_peer_sched(&wrong).is_err(), "wrong kind must fail");
}

#[test]
fn trailing_garbage_is_not_consumed() {
    // decode_event reports how many bytes it used; a frame carrying two
    // events back-to-back decodes both (the cluster protocol's emissions
    // reply packs events contiguously).
    let a = Event::DropLeaf { leaf: 1 };
    let b = Event::RuleRemoved { rule: 2 };
    let mut bytes = encode_event_vec(&a);
    let split = bytes.len();
    bytes.extend_from_slice(&encode_event_vec(&b));
    let (d1, used1) = decode_event(&bytes).unwrap();
    assert_eq!(used1, split);
    assert_eq!(fingerprint(&a), fingerprint(&d1));
    let (d2, used2) = decode_event(&bytes[used1..]).unwrap();
    assert_eq!(used1 + used2, bytes.len());
    assert_eq!(fingerprint(&b), fingerprint(&d2));
}
