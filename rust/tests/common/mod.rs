//! Shared helpers for the engine test crates: the
//! `source → fwd(p=1) → recorder(p)` edge-probe topology and its
//! no-loss / per-edge-FIFO assertions, used by both the golden
//! equivalence suite and the backpressure property tests so the two
//! cannot drift apart.

#![allow(dead_code)] // each test crate uses a subset of these helpers

use std::sync::{Arc, Mutex};
use std::time::Duration;

use samoa::core::instance::{Instance, Label};
use samoa::engine::{EngineMetrics, ThreadedEngine};
use samoa::topology::{Ctx, Event, Grouping, Processor, StreamId, TopologyBuilder};

/// Single forwarder: re-emits every instance on the given stream with
/// its id as the key (ids stay in emission order on each edge).
pub struct Fwd(pub StreamId);

impl Processor for Fwd {
    fn process(&mut self, e: Event, ctx: &mut Ctx) {
        if let Event::Instance { id, inst } = e {
            ctx.emit(self.0, id, Event::Instance { id, inst });
        }
    }
}

/// Records, per destination instance, the sequence of instance ids it
/// processed, optionally burning wall-clock per event (the slow-consumer
/// half of the backpressure stress). Ids are emitted by a single sender
/// in increasing order, so per-edge FIFO ⇔ each log is strictly
/// increasing.
pub struct Recorder {
    pub log: Arc<Mutex<Vec<Vec<u64>>>>,
    pub spin: Duration,
}

impl Processor for Recorder {
    fn process(&mut self, e: Event, ctx: &mut Ctx) {
        if !self.spin.is_zero() {
            std::thread::sleep(self.spin);
        }
        if let Event::Instance { id, .. } = e {
            self.log.lock().unwrap()[ctx.instance].push(id);
        }
    }
}

/// Run `source → fwd(p=1) → recorder(p)` on `eng`, the recorder burning
/// `spin` per event; returns the engine metrics and the per-instance id
/// logs.
pub fn run_edge_probe(
    grouping: Grouping,
    p: usize,
    n: u64,
    spin: Duration,
    eng: ThreadedEngine,
) -> (EngineMetrics, Vec<Vec<u64>>) {
    let log: Arc<Mutex<Vec<Vec<u64>>>> = Arc::new(Mutex::new(vec![Vec::new(); p]));
    let mut b = TopologyBuilder::new("probe");
    let fwd = b.add_processor("fwd", 1, |_| Box::new(Fwd(StreamId(1))));
    let log2 = Arc::clone(&log);
    let rec = b.add_processor("rec", p, move |_| {
        Box::new(Recorder { log: Arc::clone(&log2), spin })
    });
    let entry = b.stream("in", None, fwd, Grouping::Shuffle);
    b.stream("edge", Some(fwd), rec, grouping);
    let topo = b.build();
    let source = (0..n)
        .map(|id| Event::Instance { id, inst: Instance::dense(vec![id as f32], Label::None) });
    let m = eng.run(&topo, entry, source, |_, _, _| {});
    assert_eq!(m.source_instances, n);
    drop(topo); // factories hold a log clone; release before unwrapping
    let logs = Arc::try_unwrap(log).unwrap().into_inner().unwrap();
    (m, logs)
}

/// Zero loss, no duplicates, and strictly-increasing order per edge
/// (valid for `One`-routed groupings where each id reaches one
/// instance; broadcast probes assert per-instance totals instead).
pub fn assert_no_loss_fifo(logs: &[Vec<u64>], n: u64, label: &str) {
    let total: usize = logs.iter().map(|l| l.len()).sum();
    assert_eq!(total, n as usize, "{label}: lost/duplicated events");
    let mut seen: Vec<u64> = logs.iter().flatten().copied().collect();
    seen.sort_unstable();
    assert!(seen.windows(2).all(|w| w[0] < w[1]), "{label}: duplicate ids");
    for (i, l) in logs.iter().enumerate() {
        assert!(
            l.windows(2).all(|w| w[0] < w[1]),
            "{label}: edge to instance {i} reordered"
        );
    }
}
