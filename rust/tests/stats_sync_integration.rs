//! Integration tests for the delta-sync protocol: parallel pipeline
//! shards with stats-sync enabled must converge to shared statistics, so
//! prequential results at `p = 4` match the `p = 1` run within a tight
//! tolerance — for a classifier head (Hoeffding tree) *and* a regressor
//! head (AMRules) — on both the local and threaded engines. The local
//! engine is additionally bit-deterministic, and the shards' scaler
//! views must carry the *global* observation count, not their local
//! quarter. The drift-gated policy additionally has to earn its keep:
//! on a drifting stream it must converge like count-based sync while
//! shipping measurably fewer wire bytes (asserted via engine metrics).

use std::sync::Arc;

use samoa::classifiers::hoeffding_tree::{HTConfig, HoeffdingTree};
use samoa::core::model::{Classifier, Regressor};
use samoa::core::Schema;
use samoa::engine::{EngineMetrics, LocalEngine, ThreadedEngine};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::preprocess::processor::{
    build_prequential_topology_head, LearnerHead, PipelineProcessor,
};
use samoa::preprocess::{Discretizer, Pipeline, StandardScaler, SyncPolicy};
use samoa::regressors::amrules::{AMRules, AMRulesConfig};
use samoa::streams::drifting::DriftingStream;
use samoa::streams::waveform::WaveformGenerator;
use samoa::streams::StreamSource;
use samoa::topology::Event;

const N: u64 = 8000;
const SEED: u64 = 42;
const SYNC: u64 = 64;

fn classifier_head() -> LearnerHead {
    LearnerHead::Classifier(Box::new(|s: &Schema| -> Box<dyn Classifier> {
        Box::new(HoeffdingTree::new(s.clone(), HTConfig::default()))
    }))
}

fn regressor_head() -> LearnerHead {
    LearnerHead::Regressor(Box::new(|s: &Schema| -> Box<dyn Regressor> {
        Box::new(AMRules::new(s.clone(), AMRulesConfig::default()))
    }))
}

struct Outcome {
    quality: f64,
    metrics: EngineMetrics,
    /// StatsDelta + StatsGlobal wire bytes (0 without sync).
    sync_bytes: u64,
}

/// Run the prequential topology over `source`; quality is accuracy
/// (classifier) or MAE (regressor).
fn run_source(
    mut source: Box<dyn StreamSource>,
    regression: bool,
    p: usize,
    sync: Option<SyncPolicy>,
    threaded: bool,
) -> Outcome {
    let schema = source.schema().clone();
    let sink = EvalSink::new(schema.n_classes(), schema.label_range(), N);
    let sink2 = Arc::clone(&sink);
    let head = if regression { regressor_head() } else { classifier_head() };
    let (topo, handles) = build_prequential_topology_head(
        &schema,
        p,
        sync,
        move |_| {
            if regression {
                // AMRules consumes numeric attributes: scale only
                Pipeline::new().then(StandardScaler::new())
            } else {
                Pipeline::new().then(StandardScaler::new()).then(Discretizer::new(8))
            }
        },
        head,
        move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
    );
    let events =
        (0..N).map_while(|id| source.next_instance().map(|inst| Event::Instance { id, inst }));
    let m = if threaded {
        ThreadedEngine::default().run(&topo, handles.entry, events, |_, _, _| {})
    } else {
        LocalEngine::new().run(&topo, handles.entry, events, |_| {})
    };
    assert_eq!(m.source_instances, N);
    assert_eq!(m.streams[handles.prediction.0].events, N, "every instance must be scored");
    let mut sync_bytes = 0;
    if sync.is_some() && p > 1 {
        let (d, g) = (handles.delta.unwrap(), handles.global.unwrap());
        assert!(m.streams[d.0].events > 0, "sync enabled but no deltas flowed");
        assert!(m.streams[g.0].events > 0, "sync enabled but no broadcasts flowed");
        sync_bytes = m.streams[d.0].bytes + m.streams[g.0].bytes;
    }
    let quality = if regression { sink.mae() } else { sink.accuracy() };
    Outcome { quality, metrics: m, sync_bytes }
}

fn run(regression: bool, p: usize, sync: Option<SyncPolicy>, threaded: bool) -> f64 {
    let source: Box<dyn StreamSource> = if regression {
        Box::new(WaveformGenerator::new(SEED))
    } else {
        Box::new(WaveformGenerator::classification(SEED))
    };
    run_source(source, regression, p, sync, threaded).quality
}

#[test]
fn classifier_p4_with_sync_matches_p1_on_local_engine() {
    let base = run(false, 1, None, false);
    let sharded = run(false, 4, Some(SyncPolicy::Count(SYNC)), false);
    assert!(base > 0.5, "baseline accuracy {base} suspiciously low");
    assert!(
        (base - sharded).abs() < 0.05,
        "p=4+sync accuracy {sharded} drifted from p=1 accuracy {base}"
    );
}

#[test]
fn classifier_p4_with_sync_matches_p1_on_threaded_engine() {
    let base = run(false, 1, None, false);
    let sharded = run(false, 4, Some(SyncPolicy::Count(SYNC)), true);
    assert!(
        (base - sharded).abs() < 0.06,
        "threaded p=4+sync accuracy {sharded} drifted from p=1 accuracy {base}"
    );
}

#[test]
fn amrules_p4_with_sync_matches_p1_on_local_engine() {
    let base = run(true, 1, None, false);
    let sharded = run(true, 4, Some(SyncPolicy::Count(SYNC)), false);
    assert!(base < 0.8, "baseline MAE {base} suspiciously high (labels span 2.0)");
    assert!(
        (base - sharded).abs() < 0.05,
        "p=4+sync MAE {sharded} drifted from p=1 MAE {base}"
    );
}

#[test]
fn amrules_p4_with_sync_matches_p1_on_threaded_engine() {
    let base = run(true, 1, None, false);
    let sharded = run(true, 4, Some(SyncPolicy::Count(SYNC)), true);
    // wider than the local bound: threaded arrival order at the learner
    // is nondeterministic and AMRules' rule expansion is order-sensitive
    assert!(
        (base - sharded).abs() < 0.12,
        "threaded p=4+sync MAE {sharded} drifted from p=1 MAE {base}"
    );
}

#[test]
fn local_engine_sync_runs_are_deterministic() {
    let a = run(false, 4, Some(SyncPolicy::Count(SYNC)), false);
    let b = run(false, 4, Some(SyncPolicy::Count(SYNC)), false);
    assert_eq!(a, b, "identical local sync runs must be bit-identical");
}

/// The acceptance test of the adaptive policy: on a *drifting* stream,
/// drift-gated p=4 sync converges to the p=1 reference within the same
/// tolerance as count-based sync, while shipping measurably fewer
/// `StatsDelta`/`StatsGlobal` wire bytes (the gate concentrates
/// emissions at the drift points; the staleness backstop covers the
/// quiet stretches).
#[test]
fn drift_gated_sync_matches_count_accuracy_with_fewer_bytes() {
    let drifting = || -> Box<dyn StreamSource> {
        Box::new(DriftingStream::new(
            WaveformGenerator::classification(SEED),
            2000,
            2.5,
            SEED,
        ))
    };
    let base = run_source(drifting(), false, 1, None, false);
    let count = run_source(drifting(), false, 4, Some(SyncPolicy::Count(SYNC)), false);
    let drift = run_source(
        drifting(),
        false,
        4,
        Some(SyncPolicy::Drift { delta: 0.002, max_staleness: 384 }),
        false,
    );
    assert!(base.quality > 0.5, "drifting baseline accuracy {} too low", base.quality);
    assert!(
        (base.quality - count.quality).abs() < 0.05,
        "count sync accuracy {} drifted from p=1 {}",
        count.quality,
        base.quality
    );
    assert!(
        (base.quality - drift.quality).abs() < 0.05,
        "drift-gated accuracy {} drifted from p=1 {}",
        drift.quality,
        base.quality
    );
    assert!(
        (drift.sync_bytes as f64) < count.sync_bytes as f64 * 0.85,
        "drift-gated sync must ship measurably fewer bytes: {} vs {}",
        drift.sync_bytes,
        count.sync_bytes
    );
    // both runs scored every instance (metrics sanity)
    assert_eq!(count.metrics.source_instances, N);
    assert_eq!(drift.metrics.source_instances, N);
}

/// The discriminating state-level check: with sync every shard's scaler
/// view carries (close to) the *global* observation count and the shard
/// means agree tightly; without sync each shard only ever sees its own
/// quarter of the stream.
#[test]
fn shard_scaler_views_converge_to_global_statistics() {
    let p = 4usize;
    let n = 4096u64;
    let snapshots = |sync: Option<SyncPolicy>| -> Vec<Vec<f64>> {
        let mut source = WaveformGenerator::classification(7);
        let schema = source.schema().clone();
        let sink = EvalSink::new(schema.n_classes(), 1.0, n);
        let sink2 = Arc::clone(&sink);
        let (topo, handles) = build_prequential_topology_head(
            &schema,
            p,
            sync,
            |_| Pipeline::new().then(StandardScaler::new()),
            classifier_head(),
            move |_| Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) }),
        );
        let events = (0..n)
            .map_while(|id| source.next_instance().map(|inst| Event::Instance { id, inst }));
        let mut snaps = Vec::new();
        LocalEngine::new().run(&topo, handles.entry, events, |instances| {
            snaps = instances[handles.pipeline.0]
                .iter()
                .filter_map(|proc_| {
                    proc_
                        .as_any()
                        .and_then(|a| a.downcast_ref::<PipelineProcessor>())
                        .and_then(|pp| pp.pipeline().stats_snapshot(0))
                })
                .collect();
        });
        snaps
    };

    // payload layout of Moments::delta(): [n × d, mean × d, m2 × d]
    let synced = snapshots(Some(SyncPolicy::Count(32)));
    assert_eq!(synced.len(), p);
    let d = synced[0].len() / 3;
    for s in &synced {
        assert!(
            s[0] > (n as f64) * 0.9,
            "synced shard sees n={} of {n} observations on attribute 0",
            s[0]
        );
    }
    for s in &synced[1..] {
        for j in 0..d {
            assert!(
                (s[d + j] - synced[0][d + j]).abs() < 0.02,
                "synced shard means diverged on attribute {j}: {} vs {}",
                s[d + j],
                synced[0][d + j]
            );
        }
    }

    // control: without sync each shard holds only its local quarter
    let isolated = snapshots(None);
    for s in &isolated {
        assert!(
            s[0] < (n as f64) * 0.5,
            "unsynced shard unexpectedly sees global counts: n={}",
            s[0]
        );
    }
}
