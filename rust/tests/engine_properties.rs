//! Property-style tests over the engines and topology substrate: seeded
//! random topologies and event mixes, checking conservation and
//! determinism invariants (no proptest crate offline; this is a small
//! hand-rolled generator loop over many seeds) — plus the backpressure
//! contract of the bounded threaded data plane: bounded peak queue
//! depth, zero event loss, per-edge FIFO, and shutdown/`StatsSync`
//! round liveness at tiny channel capacities.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use common::{assert_no_loss_fifo, run_edge_probe};

use samoa::common::Rng;
use samoa::core::instance::{Instance, Label};
use samoa::engine::{LocalEngine, SimTimeEngine, ThreadedEngine};
use samoa::topology::{Ctx, Event, Grouping, Processor, StreamId, TopologyBuilder};

/// Forwards every instance to a configured stream (if any) and counts.
struct Fwd {
    out: Option<samoa::topology::StreamId>,
    seen: u64,
}

impl Processor for Fwd {
    fn process(&mut self, e: Event, ctx: &mut Ctx) {
        self.seen += 1;
        if let (Some(s), Event::Instance { id, inst }) = (self.out, e) {
            ctx.emit(s, id, Event::Instance { id, inst });
        }
    }

    fn mem_bytes(&self) -> usize {
        self.seen as usize
    }
}

fn inst_event(id: u64) -> Event {
    Event::Instance { id, inst: Instance::dense(vec![id as f32], Label::None) }
}

/// Random linear pipelines: events are conserved at every stage under
/// every grouping, on both engines.
#[test]
fn prop_event_conservation_random_pipelines() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let stages = 2 + rng.below(3);
        let n_events = 200 + rng.below(300) as u64;
        let groupings = [Grouping::Key, Grouping::Shuffle, Grouping::Direct];

        let mut b = TopologyBuilder::new("prop");
        let mut procs = Vec::new();
        let mut pars = Vec::new();
        for s in 0..stages {
            let par = 1 + rng.below(4);
            pars.push(par);
            // stage s forwards on stream id s+1 (entry is stream 0)
            let out = if s + 1 < stages {
                Some(samoa::topology::StreamId(s + 1))
            } else {
                None
            };
            procs.push(b.add_processor(&format!("s{s}"), par, move |_| {
                Box::new(Fwd { out, seen: 0 })
            }));
        }
        let entry = b.stream("entry", None, procs[0], Grouping::Shuffle);
        for s in 1..stages {
            let g = groupings[rng.below(groupings.len())];
            b.stream(&format!("st{s}"), Some(procs[s - 1]), procs[s], g);
        }
        let topo = b.build();

        let mut counts = vec![0u64; stages];
        let metrics = LocalEngine::new().run(&topo, entry, (0..n_events).map(inst_event), |inst| {
            for (s, row) in inst.iter().enumerate() {
                counts[s] = row.iter().map(|p| p.mem_bytes() as u64).sum();
            }
        });
        assert_eq!(metrics.source_instances, n_events, "seed {seed}");
        for (s, &c) in counts.iter().enumerate() {
            assert_eq!(c, n_events, "seed {seed}: stage {s} lost/duplicated events");
        }
    }
}

/// The local engine is deterministic: identical runs produce identical
/// stream metrics.
#[test]
fn prop_local_engine_deterministic() {
    for seed in 0..10u64 {
        let build = || {
            let mut b = TopologyBuilder::new("det");
            let a = b.add_processor("a", 3, |_| {
                Box::new(Fwd { out: Some(samoa::topology::StreamId(1)), seen: 0 })
            });
            let c = b.add_processor("c", 2, |_| Box::new(Fwd { out: None, seen: 0 }));
            let entry = b.stream("in", None, a, Grouping::Shuffle);
            b.stream("a->c", Some(a), c, Grouping::Key);
            (b.build(), entry)
        };
        let run = || {
            let (topo, entry) = build();
            let m = LocalEngine::new().run(
                &topo,
                entry,
                (0..500).map(|i| inst_event(i * seed)),
                |_| {},
            );
            (m.streams[0].events, m.streams[0].bytes, m.streams[1].events, m.streams[1].bytes)
        };
        assert_eq!(run(), run(), "seed {seed}");
    }
}

/// Threaded engine: conservation holds under concurrency for random
/// fan-out shapes.
#[test]
fn prop_threaded_conservation() {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SINK: AtomicU64 = AtomicU64::new(0);

    struct Count;
    impl Processor for Count {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            SINK.fetch_add(1, Ordering::Relaxed);
        }
    }

    for seed in 0..5u64 {
        SINK.store(0, Ordering::SeqCst);
        let mut rng = Rng::new(seed);
        let par = 1 + rng.below(6);
        let n = 500 + rng.below(1000) as u64;
        let mut b = TopologyBuilder::new("tc");
        let p = b.add_processor("w", par, |_| Box::new(Count));
        let entry = b.stream("in", None, p, Grouping::Shuffle);
        let topo = b.build();
        let m = ThreadedEngine::new(64).run(&topo, entry, (0..n).map(inst_event), |_, _, _| {});
        assert_eq!(SINK.load(Ordering::SeqCst), n, "seed {seed}");
        assert_eq!(m.streams[0].events, n, "seed {seed}");
    }
}

/// Simtime engine, `Grouping::All` with p > 1: every downstream instance
/// receives every event (n·p deliveries), the broadcast shows up in the
/// stream metrics, and the priced result stays sane. (Before this test
/// only the local/threaded paths exercised broadcasts.)
#[test]
fn prop_simtime_broadcast_all_with_parallelism() {
    for p in [2usize, 4, 7] {
        let mut b = TopologyBuilder::new("bcast");
        let head = b.add_processor("head", 1, |_| {
            Box::new(Fwd { out: Some(samoa::topology::StreamId(1)), seen: 0 })
        });
        let fan = b.add_processor("fan", p, |_| Box::new(Fwd { out: None, seen: 0 }));
        let entry = b.stream("in", None, head, Grouping::Shuffle);
        b.stream("head->fan", Some(head), fan, Grouping::All);
        let topo = b.build();

        let n = 600u64;
        let mut per_instance = Vec::new();
        let r = SimTimeEngine::default().run(&topo, entry, (0..n).map(inst_event), |inst| {
            per_instance = inst[1].iter().map(|q| q.mem_bytes() as u64).collect();
        });
        assert_eq!(r.metrics.source_instances, n, "p={p}");
        assert_eq!(r.metrics.streams[1].events, n * p as u64, "p={p}: broadcast fan-out");
        assert_eq!(per_instance.len(), p);
        for (i, &c) in per_instance.iter().enumerate() {
            assert_eq!(c, n, "p={p}: broadcast instance {i} missed events");
        }
        assert!(r.throughput() > 0.0);
        assert!(r.makespan_ns >= r.source_ns);
    }
}

/// Simtime engine, `Grouping::Key` with p > 1: conservation (no event
/// lost or duplicated), determinism (identical runs → identical stream
/// metrics and per-instance distribution), and genuine spreading across
/// the parallel instances.
#[test]
fn prop_simtime_key_routing_with_parallelism() {
    for p in [2usize, 4, 8] {
        let run = || {
            let mut b = TopologyBuilder::new("key");
            let head = b.add_processor("head", 1, |_| {
                Box::new(Fwd { out: Some(samoa::topology::StreamId(1)), seen: 0 })
            });
            let workers = b.add_processor("workers", p, |_| Box::new(Fwd { out: None, seen: 0 }));
            let entry = b.stream("in", None, head, Grouping::Shuffle);
            b.stream("head->workers", Some(head), workers, Grouping::Key);
            let topo = b.build();
            let n = 800u64;
            let mut per_instance = Vec::new();
            let r = SimTimeEngine::default().run(&topo, entry, (0..n).map(inst_event), |inst| {
                per_instance = inst[1].iter().map(|q| q.mem_bytes() as u64).collect();
            });
            (r.metrics.streams[1].events, r.metrics.streams[1].bytes, per_instance)
        };
        let (events, bytes, dist) = run();
        assert_eq!(events, 800, "p={p}: key routing lost/duplicated events");
        assert_eq!(dist.iter().sum::<u64>(), 800, "p={p}");
        // keys 0..n hash-spread: every instance must receive work
        assert!(
            dist.iter().all(|&c| c > 0),
            "p={p}: key grouping starved an instance ({dist:?})"
        );
        // determinism: the same run again routes identically
        assert_eq!((events, bytes, dist), run(), "p={p}: simtime key routing nondeterministic");
    }
}

/// Simtime: throughput is monotone non-decreasing in parallelism for an
/// embarrassingly parallel stage (up to measurement noise).
#[test]
fn prop_simtime_monotone_in_parallelism() {
    struct Burn;
    impl Processor for Burn {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            let mut x = 0u64;
            for i in 0..30_000u64 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(x);
        }
    }
    let tput = |par: usize| {
        let mut b = TopologyBuilder::new("mono");
        let p = b.add_processor("w", par, |_| Box::new(Burn));
        let entry = b.stream("in", None, p, Grouping::Shuffle);
        let topo = b.build();
        SimTimeEngine::default()
            .run(&topo, entry, (0..1500).map(inst_event), |_| {})
            .throughput()
    };
    let t1 = tput(1);
    let t4 = tput(4);
    let t8 = tput(8);
    assert!(t4 > t1, "t4={t4} t1={t1}");
    // t8 may plateau (communication) but must not collapse below t4/2
    assert!(t8 > t4 * 0.5, "t8={t8} t4={t4}");
}

// ---------------------------------------------------------------------
// Backpressure invariants (bounded threaded data plane)
// ---------------------------------------------------------------------

/// Run `f` on a helper thread and fail the test if it does not finish in
/// `secs` — a liveness watchdog, so a backpressure deadlock fails fast
/// instead of hanging the harness.
fn with_deadline<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("run did not complete in time — backpressure liveness violated")
}

// The slow-consumer stress topology (source → fwd(1) → recorder(p))
// and its loss/FIFO assertions live in tests/common, shared with the
// golden-equivalence suite.

/// The backpressure contract at channel capacities {1, 4, 64}: a fast
/// source feeding a sleeping sink keeps every resident queue bounded by
/// `capacity × batch` (plus two batches of accounting slack: the
/// consumer's not-yet-decremented batch and a safety margin), loses
/// nothing, preserves per-edge FIFO, and the producer visibly stalls —
/// while the unbounded baseline on the same topology grows its queues
/// with input size.
#[test]
fn prop_bounded_queue_depth_no_loss_fifo_at_tiny_capacities() {
    const N: u64 = 3_000;
    const P: usize = 3;
    let batch = 8usize;
    for capacity in [1usize, 4, 64] {
        let (m, logs) = with_deadline(120, move || {
            run_edge_probe(
                Grouping::Key,
                P,
                N,
                Duration::from_micros(10),
                ThreadedEngine::new(capacity).with_batch(batch),
            )
        });
        assert_no_loss_fifo(&logs, N, &format!("capacity={capacity}"));
        let bound = ((capacity + 2) * batch) as u64;
        assert!(
            m.max_peak_queue_events() <= bound,
            "capacity={capacity}: peak queue {} exceeds bound {bound}",
            m.max_peak_queue_events()
        );
        if capacity <= 4 {
            assert!(
                m.flow.backpressure_stalls > 0,
                "capacity={capacity}: slow consumer never stalled the producer"
            );
        }
        assert_eq!(m.streams[1].events, N, "capacity={capacity}");
    }
}

/// Unbounded baseline: with no backpressure the resident queue grows
/// with input size (the exact failure mode bounded channels remove).
#[test]
fn prop_unbounded_queue_grows_with_input() {
    let run = |n: u64| {
        let (m, logs) = with_deadline(120, move || {
            run_edge_probe(
                Grouping::Key,
                3,
                n,
                Duration::from_micros(20),
                ThreadedEngine::default().unbounded().with_batch(8),
            )
        });
        assert_no_loss_fifo(&logs, n, "unbounded");
        m.max_peak_queue_events()
    };
    let small = run(1_500);
    let large = run(6_000);
    assert!(
        large > small * 2,
        "unbounded peak depth did not grow with input: {small} -> {large}"
    );
    // and it dwarfs what any tiny bounded config would allow
    assert!(large > (4 + 2) * 8, "unbounded run barely queued ({large})");
}

/// Work-stealing mode under the same slow-consumer stress: zero loss,
/// per-edge FIFO, bounded resident depth — with fewer workers than
/// instances and parked batches standing in for blocking sends.
#[test]
fn prop_steal_mode_backpressure_no_loss_fifo() {
    const N: u64 = 2_000;
    let batch = 8usize;
    for capacity in [1usize, 4] {
        let (m, logs) = with_deadline(120, move || {
            run_edge_probe(
                Grouping::Key,
                3,
                N,
                Duration::from_micros(10),
                ThreadedEngine::new(capacity).with_batch(batch).with_workers(2),
            )
        });
        assert_no_loss_fifo(&logs, N, &format!("steal capacity={capacity}"));
        let bound = ((capacity + 2) * batch) as u64;
        assert!(
            m.max_peak_queue_events() <= bound,
            "steal capacity={capacity}: peak {} exceeds bound {bound}",
            m.max_peak_queue_events()
        );
        assert!(m.flow.backpressure_stalls > 0, "steal capacity={capacity}: no stalls");
    }
}

/// `StatsSync` round liveness under backpressure: the delta/global sync
/// loop rides the unbounded control plane, so rounds complete and the
/// master merges every observation exactly once even when the data
/// channels hold a single batch — on the pinned and the work-stealing
/// scheduler alike.
#[test]
fn prop_statssync_rounds_live_under_tiny_capacity() {
    use samoa::core::Schema;
    use samoa::preprocess::processor::PipelineProcessor;
    use samoa::preprocess::{Pipeline, StandardScaler, StatsSyncProcessor, SyncPolicy};
    use samoa::streams::waveform::WaveformGenerator;
    use samoa::streams::StreamSource;

    const N: u64 = 2_048;
    const P: usize = 4;
    const INTERVAL: u64 = 16;

    let seen = Arc::new(AtomicU64::new(0));

    struct CountSink(Arc<AtomicU64>);
    impl Processor for CountSink {
        fn process(&mut self, _e: Event, _c: &mut Ctx) {
            std::thread::sleep(Duration::from_micros(5));
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    for (capacity, workers) in [(1usize, None), (4, None), (64, None), (4, Some(2usize))] {
        let seen2 = Arc::clone(&seen);
        seen.store(0, Ordering::SeqCst);
        let (deltas, master_n, broadcasts, completed, skew) = with_deadline(180, move || {
            let schema: Schema = WaveformGenerator::classification(1).schema().clone();
            let out = StreamId(1);
            let delta = StreamId(2);
            let global = StreamId(3);

            let mut b = TopologyBuilder::new("sync-bp");
            let s = schema.clone();
            let pipe = b.add_processor("pipeline", P, move |_| {
                Box::new(
                    PipelineProcessor::new(
                        Pipeline::new().then(StandardScaler::new()),
                        &s,
                        out,
                    )
                    .with_sync(SyncPolicy::Count(INTERVAL), delta),
                )
            });
            let sink = b.add_processor("sink", 1, move |_| {
                Box::new(CountSink(Arc::clone(&seen2)))
            });
            let s2 = schema.clone();
            let stats = b.add_processor("stats-sync", 1, move |_| {
                Box::new(StatsSyncProcessor::new(
                    Pipeline::new().then(StandardScaler::new()),
                    &s2,
                    global,
                    P,
                ))
            });
            let entry = b.stream("instance", None, pipe, Grouping::Shuffle);
            let s_out = b.stream("transformed", Some(pipe), sink, Grouping::Shuffle);
            let s_delta = b.stream("stats-delta", Some(pipe), stats, Grouping::Key);
            let s_global = b.stream("stats-global", Some(stats), pipe, Grouping::All);
            assert_eq!((s_out, s_delta, s_global), (out, delta, global));
            let topo = b.build();

            let mut stream = WaveformGenerator::classification(1);
            let source = (0..N)
                .map_while(move |id| {
                    stream.next_instance().map(|inst| Event::Instance { id, inst })
                });
            let mut eng = ThreadedEngine::new(capacity).with_batch(8);
            if let Some(w) = workers {
                eng = eng.with_workers(w);
            }
            let mut extracted = (0u64, 0.0f64, 0u64, 0u64, 0u64);
            eng.run(&topo, entry, source, |pid, _iid, proc_| {
                if pid == 2 {
                    if let Some(agg) = proc_
                        .as_any()
                        .and_then(|a| a.downcast_ref::<StatsSyncProcessor>())
                    {
                        extracted = (
                            agg.deltas_merged(),
                            agg.snapshot(0).map_or(0.0, |s| s[0]),
                            agg.broadcasts(),
                            agg.completed_rounds(),
                            agg.skew_rounds(),
                        );
                    }
                }
            });
            extracted
        });
        let label = format!("capacity={capacity} workers={workers:?}");
        // every shard emits exactly N/P/INTERVAL deltas; all are merged
        let waves = N / P as u64 / INTERVAL;
        assert_eq!(deltas, waves * P as u64, "{label}");
        assert_eq!(master_n, N as f64, "{label}: master lost observations");
        assert!(
            broadcasts >= waves && broadcasts <= deltas,
            "{label}: broadcasts {broadcasts} outside [{waves}, {deltas}]"
        );
        assert_eq!(completed + skew, broadcasts, "{label}");
        assert_eq!(seen.load(Ordering::SeqCst), N, "{label}: sink lost instances");
    }
}
