//! Property tests for the merge laws every `MergeableState` impl must
//! satisfy (hand-rolled seed loops, like `engine_properties.rs` — no
//! proptest crate offline). The delta-sync protocol
//! (`preprocess::sync`) relies on exactly these laws: the aggregator
//! folds shard increments in arbitrary order, so
//!
//! * `merge` must be **commutative** (exactly, up to f64 rounding),
//! * `merge` must be **associative** — exactly for exact summaries
//!   (moments, min/max, CountMin, equal-range histograms), within the
//!   summary's own approximation bound for lossy ones (Misra-Gries,
//!   re-binned histograms),
//! * the `reset` state must be the **identity**,
//! * `apply_delta(delta())` must **round-trip**.
//!
//! Plus the headline law: merged Welford moments equal the single-pass
//! moments of the concatenated stream.

use samoa::common::Rng;
use samoa::core::instance::{Instance, Label};
use samoa::core::Schema;
use samoa::preprocess::merge::payloads_close;
use samoa::preprocess::{
    CountMinSketch, Discretizer, MergeableState, MinMaxScaler, MisraGries, StandardScaler,
    Transform,
};

const DIM: usize = 3;

fn schema() -> Schema {
    Schema::classification("t", Schema::all_numeric(DIM), 2)
}

fn random_instance(rng: &mut Rng) -> Instance {
    let vals: Vec<f32> = (0..DIM).map(|_| (rng.gaussian() * 5.0 + 1.0) as f32).collect();
    Instance::dense(vals, Label::None)
}

/// Deterministic scaler over `n` seeded instances (rebuildable copies —
/// the transforms are not `Clone`, so "copies" are re-fed streams).
fn scaler(seed: u64, n: usize) -> StandardScaler {
    let mut s = StandardScaler::new();
    s.bind(&schema());
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        s.transform(random_instance(&mut rng)).unwrap();
    }
    s
}

fn minmax(seed: u64, n: usize) -> MinMaxScaler {
    let mut s = MinMaxScaler::new();
    s.bind(&schema());
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        s.transform(random_instance(&mut rng)).unwrap();
    }
    s
}

/// Discretizer whose warmup prefix comes from a shared seed, so every
/// instance built with the same `warm_seed` freezes on the *same* range
/// (the regime where histogram merge is exact); `seed` then drives the
/// post-freeze values.
fn discretizer(warm_seed: u64, seed: u64, n: usize) -> Discretizer {
    let mut d = Discretizer::with_resolution(4, 32, 64);
    d.bind(&schema());
    let mut wrng = Rng::new(warm_seed);
    for _ in 0..32 {
        d.transform(random_instance(&mut wrng)).unwrap();
    }
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        d.transform(random_instance(&mut rng)).unwrap();
    }
    d
}

fn countmin(seed: u64, n: usize) -> CountMinSketch {
    let mut cm = CountMinSketch::new(128, 4);
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        cm.add(rng.below(200) as u64, 1 + rng.below(3) as u64);
    }
    cm
}

fn misra_gries(seed: u64, n: usize) -> (MisraGries, std::collections::HashMap<u64, u64>) {
    let mut mg = MisraGries::new(12);
    let mut truth = std::collections::HashMap::new();
    let mut rng = Rng::new(seed);
    for _ in 0..n {
        // skewed stream: a few heavy items over a noise tail
        let x = if rng.below(2) == 0 { rng.below(4) as u64 } else { 10 + rng.below(400) as u64 };
        mg.add(x);
        *truth.entry(x).or_insert(0u64) += 1;
    }
    (mg, truth)
}

// --------------------------------------------------------- commutativity

#[test]
fn prop_merge_commutative_scalers_and_sketches() {
    for seed in 0..8u64 {
        let (sa, sb) = (100 + seed, 200 + seed);
        let (na, nb) = (500 + 40 * seed as usize, 300 + 25 * seed as usize);

        let mut ab = scaler(sa, na);
        ab.merge(&scaler(sb, nb));
        let mut ba = scaler(sb, nb);
        ba.merge(&scaler(sa, na));
        assert!(
            payloads_close(&ab.delta(), &ba.delta(), 1e-9),
            "seed {seed}: StandardScaler merge not commutative"
        );

        let mut ab = minmax(sa, na);
        ab.merge(&minmax(sb, nb));
        let mut ba = minmax(sb, nb);
        ba.merge(&minmax(sa, na));
        assert_eq!(ab.delta(), ba.delta(), "seed {seed}: MinMaxScaler merge not commutative");

        let mut ab = discretizer(7, sa, na);
        ab.merge(&discretizer(7, sb, nb));
        let mut ba = discretizer(7, sb, nb);
        ba.merge(&discretizer(7, sa, na));
        assert!(
            payloads_close(&ab.delta(), &ba.delta(), 1e-9),
            "seed {seed}: Discretizer merge not commutative (equal ranges)"
        );

        let mut ab = countmin(sa, na);
        ab.merge(&countmin(sb, nb));
        let mut ba = countmin(sb, nb);
        ba.merge(&countmin(sa, na));
        assert_eq!(ab.delta(), ba.delta(), "seed {seed}: CountMin merge not commutative");

        let (mut ab, _) = misra_gries(sa, na);
        ab.merge(&misra_gries(sb, nb).0);
        let (mut ba, _) = misra_gries(sb, nb);
        ba.merge(&misra_gries(sa, na).0);
        assert_eq!(ab.delta(), ba.delta(), "seed {seed}: MisraGries merge not commutative");
    }
}

#[test]
fn prop_merge_commutative_discretizer_disjoint_ranges() {
    // different warmup seeds ⇒ different frozen ranges ⇒ the re-binning
    // path; counter mass still lands identically in either merge order
    for seed in 0..6u64 {
        let (na, nb) = (200 + 10 * seed as usize, 150 + 5 * seed as usize);
        let mut ab = discretizer(1 + seed, 100 + seed, na);
        ab.merge(&discretizer(50 + seed, 200 + seed, nb));
        let mut ba = discretizer(50 + seed, 200 + seed, nb);
        ba.merge(&discretizer(1 + seed, 100 + seed, na));
        assert!(
            payloads_close(&ab.delta(), &ba.delta(), 1e-9),
            "seed {seed}: Discretizer re-binning merge not commutative"
        );
    }
}

// --------------------------------------------------------- associativity

#[test]
fn prop_merge_associative_exact_summaries() {
    for seed in 0..8u64 {
        let seeds = [300 + seed, 400 + seed, 500 + seed];
        let ns = [400usize, 250, 150];

        // (A ⊕ B) ⊕ C
        let mut left = scaler(seeds[0], ns[0]);
        left.merge(&scaler(seeds[1], ns[1]));
        left.merge(&scaler(seeds[2], ns[2]));
        // A ⊕ (B ⊕ C)
        let mut bc = scaler(seeds[1], ns[1]);
        bc.merge(&scaler(seeds[2], ns[2]));
        let mut right = scaler(seeds[0], ns[0]);
        right.merge(&bc);
        assert!(
            payloads_close(&left.delta(), &right.delta(), 1e-6),
            "seed {seed}: StandardScaler merge not associative"
        );

        let mut left = minmax(seeds[0], ns[0]);
        left.merge(&minmax(seeds[1], ns[1]));
        left.merge(&minmax(seeds[2], ns[2]));
        let mut bc = minmax(seeds[1], ns[1]);
        bc.merge(&minmax(seeds[2], ns[2]));
        let mut right = minmax(seeds[0], ns[0]);
        right.merge(&bc);
        assert_eq!(left.delta(), right.delta(), "seed {seed}: MinMaxScaler not associative");

        let mut left = countmin(seeds[0], ns[0]);
        left.merge(&countmin(seeds[1], ns[1]));
        left.merge(&countmin(seeds[2], ns[2]));
        let mut bc = countmin(seeds[1], ns[1]);
        bc.merge(&countmin(seeds[2], ns[2]));
        let mut right = countmin(seeds[0], ns[0]);
        right.merge(&bc);
        assert_eq!(left.delta(), right.delta(), "seed {seed}: CountMin not associative");

        // equal-range histograms: pointwise adds, exactly associative
        let mut left = discretizer(9, seeds[0], ns[0]);
        left.merge(&discretizer(9, seeds[1], ns[1]));
        left.merge(&discretizer(9, seeds[2], ns[2]));
        let mut bc = discretizer(9, seeds[1], ns[1]);
        bc.merge(&discretizer(9, seeds[2], ns[2]));
        let mut right = discretizer(9, seeds[0], ns[0]);
        right.merge(&bc);
        assert!(
            payloads_close(&left.delta(), &right.delta(), 1e-9),
            "seed {seed}: equal-range Discretizer merge not associative"
        );
    }
}

#[test]
fn prop_merge_associative_discretizer_within_rank_tolerance() {
    // disjoint ranges: re-binning is lossy, so grouping may differ — but
    // only by mass shifted within ~one fine cell; rank queries from the
    // two merge trees must stay close
    for seed in 0..6u64 {
        let mk = |i: u64, n: usize| discretizer(20 * (i + 1) + seed, 600 + i + seed, n);
        let mut left = mk(0, 300);
        left.merge(&mk(1, 200));
        left.merge(&mk(2, 250));
        let mut bc = mk(1, 200);
        bc.merge(&mk(2, 250));
        let mut right = mk(0, 300);
        right.merge(&bc);
        for probe in -8..=8 {
            let x = probe as f64 * 2.0;
            for j in 0..DIM {
                let (a, b) = (left.rank(j, x), right.rank(j, x));
                assert!(
                    (a - b).abs() < 0.1,
                    "seed {seed}: rank({j}, {x}) {a} vs {b} diverged across merge trees"
                );
            }
        }
    }
}

#[test]
fn prop_merge_associative_misra_gries_within_error_bound() {
    // counter values may differ by grouping, but every merge tree must
    // preserve the MG guarantee: count - N/k <= estimate <= count
    for seed in 0..6u64 {
        let parts: Vec<(MisraGries, std::collections::HashMap<u64, u64>)> =
            (0..3).map(|i| misra_gries(700 + 10 * i + seed, 3000 + 500 * i as usize)).collect();
        let mut truth = std::collections::HashMap::new();
        for (_, t) in &parts {
            for (&x, &c) in t {
                *truth.entry(x).or_insert(0u64) += c;
            }
        }
        let n: u64 = truth.values().sum();
        let k = parts[0].0.k() as u64;

        let rebuild = |i: usize| {
            let (mg, _) = misra_gries(700 + 10 * i as u64 + seed, 3000 + 500 * i);
            mg
        };
        let mut left = rebuild(0);
        left.merge(&rebuild(1));
        left.merge(&rebuild(2));
        let mut bc = rebuild(1);
        bc.merge(&rebuild(2));
        let mut right = rebuild(0);
        right.merge(&bc);

        for tree in [&left, &right] {
            assert_eq!(tree.total(), n);
            for (&x, &c) in &truth {
                let est = tree.estimate(x);
                assert!(est <= c, "seed {seed}: item {x} overestimated ({est} > {c})");
                assert!(
                    est + n / k >= c,
                    "seed {seed}: item {x} est {est} below {c} - N/k"
                );
            }
        }
        // and the two trees' estimates agree within the composed bound
        for &x in truth.keys() {
            let (a, b) = (left.estimate(x), right.estimate(x));
            assert!(
                a.abs_diff(b) <= n / k,
                "seed {seed}: item {x} estimates {a} vs {b} differ by more than N/k"
            );
        }
    }
}

// ------------------------------------------------- identity + round trip

#[test]
fn prop_reset_state_is_merge_identity() {
    let mut s = scaler(42, 500);
    let before = s.delta();
    let mut empty = StandardScaler::new();
    empty.bind(&schema());
    s.merge(&empty);
    assert_eq!(s.delta(), before, "merging an empty scaler changed state");

    let mut m = minmax(42, 500);
    let before = m.delta();
    let mut empty = MinMaxScaler::new();
    empty.bind(&schema());
    m.merge(&empty);
    assert_eq!(m.delta(), before);

    let mut d = discretizer(3, 42, 300);
    let before = d.delta();
    let mut empty = Discretizer::with_resolution(4, 32, 64);
    empty.bind(&schema());
    d.merge(&empty);
    assert_eq!(d.delta(), before);

    let mut cm = countmin(42, 500);
    let before = cm.delta();
    cm.merge(&CountMinSketch::new(128, 4));
    assert_eq!(cm.delta(), before);

    let (mut mg, _) = misra_gries(42, 500);
    let before = mg.delta();
    mg.merge(&MisraGries::new(12));
    assert_eq!(mg.delta(), before);
}

#[test]
fn prop_delta_apply_round_trips() {
    for seed in 0..5u64 {
        let s = scaler(seed, 400);
        let mut t = StandardScaler::new();
        t.bind(&schema());
        t.apply_delta(&s.delta());
        assert_eq!(t.delta(), s.delta(), "seed {seed}: scaler round trip");

        let m = minmax(seed, 400);
        let mut t = MinMaxScaler::new();
        t.bind(&schema());
        t.apply_delta(&m.delta());
        assert_eq!(t.delta(), m.delta(), "seed {seed}: minmax round trip");

        let d = discretizer(5, seed, 300);
        let mut t = Discretizer::with_resolution(4, 32, 64);
        t.bind(&schema());
        t.apply_delta(&d.delta());
        assert_eq!(t.delta(), d.delta(), "seed {seed}: discretizer round trip");

        let cm = countmin(seed, 400);
        let mut t = CountMinSketch::new(1, 1);
        t.apply_delta(&cm.delta());
        assert_eq!(t.delta(), cm.delta(), "seed {seed}: countmin round trip");

        let (mg, _) = misra_gries(seed, 400);
        let mut t = MisraGries::new(12);
        t.apply_delta(&mg.delta());
        assert_eq!(t.delta(), mg.delta(), "seed {seed}: misra-gries round trip");
    }
}

// ------------------------------------------- the headline Welford law

#[test]
fn prop_merged_welford_equals_single_pass_on_concatenated_stream() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(900 + seed);
        let shards = 2 + (seed as usize % 4); // 2..=5 shards
        let n = 1000 + 100 * seed as usize;

        let mut parts: Vec<StandardScaler> = (0..shards)
            .map(|_| {
                let mut s = StandardScaler::new();
                s.bind(&schema());
                s
            })
            .collect();
        let mut single = StandardScaler::new();
        single.bind(&schema());

        for i in 0..n {
            let inst = random_instance(&mut rng);
            parts[i % shards].transform(inst.clone()).unwrap();
            single.transform(inst).unwrap();
        }

        let mut merged = parts.remove(0);
        for p in &parts {
            merged.merge(p);
        }
        assert!(
            payloads_close(&merged.delta(), &single.delta(), 1e-7),
            "seed {seed}: merged moments != single-pass moments over the concatenated stream"
        );
        // and the derived statistics agree
        for j in 0..DIM {
            assert!((merged.mean(j) - single.mean(j)).abs() < 1e-9, "seed {seed} mean {j}");
            assert!(
                (merged.moments().sd(j) - single.moments().sd(j)).abs() < 1e-9,
                "seed {seed} sd {j}"
            );
        }
    }
}
