//! End-to-end distributed AMRules: VAMR and HAMR topologies on the local
//! and threaded engines against the sequential MAMR baseline.

use std::sync::Arc;

use samoa::core::model::Regressor;
use samoa::engine::{LocalEngine, ThreadedEngine};
use samoa::evaluation::prequential::{EvalSink, EvaluatorProcessor};
use samoa::regressors::amrules::{AMRules, AMRulesConfig};
use samoa::regressors::{hamr, vamr};
use samoa::streams::{datasets::ElectricityRegStream, StreamSource};
use samoa::topology::Event;

const N: u64 = 30_000;

fn mamr_rmse(seed: u64) -> f64 {
    let mut stream = ElectricityRegStream::with_limit(seed, N);
    let mut model = AMRules::new(stream.schema().clone(), AMRulesConfig::default());
    let mut sq = 0.0;
    let mut n = 0u64;
    while let Some(inst) = stream.next_instance() {
        let y = inst.numeric_label().unwrap();
        let e = y - model.predict(&inst);
        sq += e * e;
        n += 1;
        model.train(&inst);
    }
    (sq / n as f64).sqrt()
}

#[test]
fn vamr_topology_tracks_mamr() {
    let base = mamr_rmse(5);

    let mut stream = ElectricityRegStream::with_limit(5, N);
    let range = stream.schema().label_range();
    let sink = EvalSink::new(0, range, 100_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) =
        vamr::build_topology(stream.schema(), &AMRulesConfig::default(), 2, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
    let source = (0..N).map(move |id| Event::Instance {
        id,
        inst: stream.next_instance().unwrap(),
    });
    LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    let vamr_rmse = sink.rmse();
    assert!(vamr_rmse.is_finite() && vamr_rmse > 0.0);
    // distributed must stay in the same error regime as sequential
    assert!(
        vamr_rmse < base * 2.0 + 0.2,
        "VAMR rmse {vamr_rmse:.4} vs MAMR {base:.4}"
    );
}

#[test]
fn hamr_topology_with_replicated_mas() {
    let mut stream = ElectricityRegStream::with_limit(9, N);
    let range = stream.schema().label_range();
    let sink = EvalSink::new(0, range, 100_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) =
        hamr::build_topology(stream.schema(), &AMRulesConfig::default(), 2, 2, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
    let source = (0..N).map(move |id| Event::Instance {
        id,
        inst: stream.next_instance().unwrap(),
    });
    let metrics = LocalEngine::new().run(&topo, handles.entry, source, |_| {});
    assert_eq!(metrics.source_instances, N);
    // rules were created and broadcast: new-rule→MAs stream carried events
    assert!(
        metrics.streams[handles.streams.new_rule_to_mas.0].events > 0,
        "DRL never broadcast a rule"
    );
    let rmse = sink.rmse();
    assert!(rmse.is_finite() && rmse < 2.0, "HAMR rmse {rmse}");
}

#[test]
fn vamr_on_threaded_engine() {
    let mut stream = ElectricityRegStream::with_limit(11, 15_000);
    let range = stream.schema().label_range();
    let sink = EvalSink::new(0, range, 100_000);
    let sink2 = Arc::clone(&sink);
    let (topo, handles) =
        vamr::build_topology(stream.schema(), &AMRulesConfig::default(), 2, move |_| {
            Box::new(EvaluatorProcessor { sink: Arc::clone(&sink2) })
        });
    let source = (0..15_000u64).map(move |id| Event::Instance {
        id,
        inst: stream.next_instance().unwrap(),
    });
    let metrics = ThreadedEngine::default().run(&topo, handles.entry, source, |_, _, _| {});
    assert_eq!(metrics.source_instances, 15_000);
    assert!(sink.rmse().is_finite());
}

#[test]
fn mamr_table5_statistics_nontrivial() {
    // Table 5 shape: airlines (complex) creates far more rules/features
    // than electricity (simple)
    let mut elec = ElectricityRegStream::with_limit(3, 40_000);
    let mut m1 = AMRules::new(elec.schema().clone(), AMRulesConfig::default());
    while let Some(i) = elec.next_instance() {
        m1.train(&i);
    }
    let mut air = samoa::streams::datasets::AirlinesStream::with_limit(3, 40_000);
    let mut m2 = AMRules::new(air.schema().clone(), AMRulesConfig::default());
    while let Some(i) = air.next_instance() {
        m2.train(&i);
    }
    assert!(m1.stats.rules_created > 0);
    assert!(m2.stats.rules_created > 0);
    assert!(
        m2.stats.features_created >= m1.stats.features_created,
        "airlines ({}) should be at least as complex as electricity ({})",
        m2.stats.features_created,
        m1.stats.features_created
    );
}
