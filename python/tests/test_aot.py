"""AOT pipeline tests: every entrypoint lowers to parseable HLO text and
the manifest matches the compile-time shapes the rust side expects."""

import os

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    written = aot.lower_all(str(out))
    return out, written


def test_all_entrypoints_written(lowered):
    out, written = lowered
    assert set(written) == {"infogain", "sdr", "cluster"}
    for name, (path, size) in written.items():
        assert os.path.exists(path)
        assert size > 1000, f"{name} suspiciously small"


def test_hlo_is_text_with_entry(lowered):
    out, written = lowered
    for name, (path, _) in written.items():
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text


def test_manifest_shapes(lowered):
    out, _ = lowered
    with open(os.path.join(out, "manifest.txt")) as f:
        lines = f.read().splitlines()
    assert f"ig_shape {model.IG_A} {model.IG_V} {model.IG_C}" in lines
    assert f"sdr_shape {model.SDR_A} {model.SDR_B}" in lines
    assert f"cluster_shape {model.CL_N} {model.CL_K} {model.CL_D}" in lines


def test_lowering_is_deterministic():
    spec = jax.ShapeDtypeStruct((model.IG_A, model.IG_V, model.IG_C), "float32")
    a = aot.to_hlo_text(jax.jit(model.infogain_top2).lower(spec))
    b = aot.to_hlo_text(jax.jit(model.infogain_top2).lower(spec))
    assert a == b
