"""Kernel-vs-oracle correctness: the CORE numeric signal of the build.

Every Pallas kernel must match its pure-jnp reference (ref.py) to float32
tolerance on dense random inputs, adversarial inputs (zeros, padding,
single-class leaves), and hypothesis-generated shape/value sweeps.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import cluster_assign_ref, infogain_ref, sdr_ref
from compile.kernels.infogain import infogain
from compile.kernels.sdr import sdr
from compile.kernels.cluster import cluster_assign
from compile import model

def counters(a=64, v=16, c=8, scale=50.0, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.random((a, v, c)).astype(np.float32) * scale).round()


# ---------------------------------------------------------------- infogain

class TestInfogain:
    def test_matches_ref_random(self):
        n = counters(seed=1)
        g, s = infogain(n)
        gr, sr = infogain_ref(n)
        np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(s, sr, rtol=1e-5, atol=1e-5)

    def test_all_zero_padding_gains_zero(self):
        n = np.zeros((64, 16, 8), np.float32)
        g, s = infogain(n)
        assert np.all(g == 0.0) and np.all(s == 0.0)

    def test_partial_padding(self):
        n = counters(seed=2)
        n[40:] = 0.0  # attributes 40.. are padding
        g, _ = infogain(n)
        gr, _ = infogain_ref(n)
        assert np.all(g[40:] == 0.0)
        np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)

    def test_pure_leaf_zero_gain(self):
        # all mass in one class -> H_before = 0 -> gain must be 0
        n = np.zeros((64, 16, 8), np.float32)
        n[:, :, 3] = 7.0
        g, _ = infogain(n)
        np.testing.assert_allclose(g, 0.0, atol=1e-6)

    def test_perfect_split_gain_equals_class_entropy(self):
        # attribute 0: value v fully determines class v%2 over 2 classes
        n = np.zeros((64, 16, 8), np.float32)
        for v in range(16):
            n[0, v, v % 2] = 10.0
        g, _ = infogain(n)
        # H(class) = 1 bit (balanced 2 classes), H(class|value) = 0
        np.testing.assert_allclose(g[0], 1.0, rtol=1e-5)

    def test_gain_nonnegative_many_seeds(self):
        for seed in range(8):
            g, _ = infogain(counters(seed=seed))
            assert np.all(np.asarray(g) >= -1e-5)

    def test_multi_block_grid(self):
        n = counters(a=256, seed=3)
        g, _ = infogain(n)
        gr, _ = infogain_ref(n)
        np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 3),
        v=st.sampled_from([2, 4, 16]),
        c=st.sampled_from([2, 8]),
        scale=st.floats(1.0, 1e4),
    )
    def test_hypothesis_sweep(self, seed, blocks, v, c, scale):
        rng = np.random.default_rng(seed)
        a = 64 * blocks
        n = (rng.random((a, v, c)).astype(np.float32) * scale).round()
        # randomly zero some attribute rows (padding) and value slices
        mask = rng.random(a) < 0.2
        n[mask] = 0.0
        g, s = infogain(n)
        gr, sr = infogain_ref(n)
        np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s, sr, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------- sdr

def bin_stats(a=32, b=64, seed=0, n_scale=20.0):
    """Random but *consistent* (count, sum, sumsq) triples: generate raw
    samples per bin so that sumsq >= sum^2/count always holds."""
    rng = np.random.default_rng(seed)
    out = np.zeros((a, b, 3), np.float32)
    counts = rng.integers(0, int(n_scale), size=(a, b))
    for i in range(a):
        for j in range(b):
            k = counts[i, j]
            if k:
                ys = rng.normal(loc=rng.normal(), scale=1.0, size=k)
                out[i, j] = (k, ys.sum(), (ys * ys).sum())
    return out


class TestSdr:
    def test_matches_ref_random(self):
        s = bin_stats(seed=1)
        np.testing.assert_allclose(sdr(s), sdr_ref(s), rtol=1e-4, atol=1e-4)

    def test_zero_padding(self):
        s = np.zeros((32, 64, 3), np.float32)
        assert np.all(np.asarray(sdr(s)) == 0.0)

    def test_empty_side_invalid(self):
        # all mass in bin 0 -> only threshold b=0 has non-empty left,
        # but its right side is empty -> entire surface must be 0
        s = np.zeros((32, 64, 3), np.float32)
        s[:, 0] = (10.0, 5.0, 40.0)
        assert np.all(np.asarray(sdr(s)) == 0.0)

    def test_perfect_separation_max_at_boundary(self):
        # bins 0..31 contain target=0, bins 32.. contain target=10:
        # SDR maximal at threshold 31
        s = np.zeros((32, 64, 3), np.float32)
        s[:, :32] = (5.0, 0.0, 0.0)
        s[:, 32:] = (5.0, 50.0, 500.0)
        surf = np.asarray(sdr(s))
        assert np.all(surf.argmax(axis=1) == 31)

    def test_sdr_nonnegative(self):
        for seed in range(5):
            surf = np.asarray(sdr(bin_stats(seed=seed)))
            assert np.all(surf >= -1e-4)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_scale=st.floats(1.0, 50.0))
    def test_hypothesis_sweep(self, seed, n_scale):
        s = bin_stats(seed=seed, n_scale=n_scale)
        np.testing.assert_allclose(sdr(s), sdr_ref(s), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------- cluster

class TestCluster:
    def _case(self, seed=0, n=128, k=128, d=64, live=32):
        rng = np.random.default_rng(seed)
        pts = rng.normal(size=(n, d)).astype(np.float32)
        ctr = rng.normal(size=(k, d)).astype(np.float32)
        w = np.zeros(k, np.float32)
        w[:live] = rng.random(live).astype(np.float32) + 0.1
        return pts, ctr, w

    def test_matches_ref(self):
        pts, ctr, w = self._case(seed=1)
        idx, d2 = cluster_assign(pts, ctr, w)
        idx_r, d2_r = cluster_assign_ref(pts, ctr, w)
        np.testing.assert_array_equal(idx, idx_r)
        np.testing.assert_allclose(d2, d2_r, rtol=1e-4, atol=1e-4)

    def test_dead_slots_never_win(self):
        pts, ctr, w = self._case(seed=2, live=16)
        # make a dead centroid exactly equal to point 0: must still lose
        ctr[100] = pts[0]
        w[100] = 0.0
        idx, _ = cluster_assign(pts, ctr, w)
        assert np.asarray(idx)[0] != 100
        assert np.all(np.asarray(idx) < 16)

    def test_exact_match_distance_zero(self):
        pts, ctr, w = self._case(seed=3)
        ctr[5] = pts[7]
        w[5] = 1.0
        idx, d2 = cluster_assign(pts, ctr, w)
        assert np.asarray(idx)[7] == 5
        assert np.asarray(d2)[7] < 1e-3

    def test_brute_force_small(self):
        pts, ctr, w = self._case(seed=4, live=128)
        idx, d2 = cluster_assign(pts, ctr, w)
        brute = ((pts[:, None, :] - ctr[None, :, :]) ** 2).sum(-1)
        np.testing.assert_array_equal(idx, brute.argmin(1))
        np.testing.assert_allclose(d2, brute.min(1), rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), live=st.integers(1, 128))
    def test_hypothesis_sweep(self, seed, live):
        pts, ctr, w = self._case(seed=seed, live=live)
        idx, d2 = cluster_assign(pts, ctr, w)
        idx_r, d2_r = cluster_assign_ref(pts, ctr, w)
        # ties can differ in index; distances must agree
        np.testing.assert_allclose(d2, d2_r, rtol=1e-3, atol=1e-3)
        assert np.all(np.asarray(idx) < live)


# ------------------------------------------------------------- L2 model

class TestModelEntrypoints:
    def test_infogain_top2(self):
        n = counters(seed=5)
        gain, best_idx, best, second = model.infogain_top2(n)
        g = np.asarray(gain)
        assert g.shape == (model.IG_A,)
        assert int(best_idx) == g.argmax()
        np.testing.assert_allclose(float(best), g.max(), rtol=1e-6)
        np.testing.assert_allclose(
            float(second), np.partition(g, -2)[-2], rtol=1e-5, atol=1e-6)

    def test_sdr_best(self):
        s = bin_stats(seed=6)
        surf, best_idx, best, second = model.sdr_best(s)
        f = np.asarray(surf).reshape(-1)
        assert int(best_idx) == f.argmax()
        np.testing.assert_allclose(float(best), f.max(), rtol=1e-6)

    def test_cluster_step_shapes(self):
        rng = np.random.default_rng(7)
        idx, d2 = model.cluster_step(
            rng.normal(size=(model.CL_N, model.CL_D)).astype(np.float32),
            rng.normal(size=(model.CL_K, model.CL_D)).astype(np.float32),
            np.ones(model.CL_K, np.float32),
        )
        assert np.asarray(idx).shape == (model.CL_N,)
        assert np.asarray(d2).shape == (model.CL_N,)
