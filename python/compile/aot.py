"""AOT: lower each L2 entrypoint to HLO *text* for the rust PJRT runtime.

HLO text (not serialized HloModuleProto, not jax.export): jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects with `proto.id() <= INT_MAX`.
The HLO text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Produces one `<name>.hlo.txt` per entry in model.ENTRYPOINTS plus a
`manifest.txt` recording shapes, for the rust artifact registry to sanity-
check against rust/src/runtime/shapes.rs.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text, with return_tuple=True.

    return_tuple=True means the rust side always unwraps a tuple literal
    (Literal::to_tuple), uniformly for single- and multi-output fns.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    args = model.example_args()
    written = {}
    for name, fn in model.ENTRYPOINTS.items():
        lowered = jax.jit(fn).lower(*args[name])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written[name] = (path, len(text))
    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write(f"ig_shape {model.IG_A} {model.IG_V} {model.IG_C}\n")
        f.write(f"sdr_shape {model.SDR_A} {model.SDR_B}\n")
        f.write(f"cluster_shape {model.CL_N} {model.CL_K} {model.CL_D}\n")
        for name, (path, size) in written.items():
            f.write(f"artifact {name} {os.path.basename(path)} {size}\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    a = ap.parse_args()
    for name, (path, size) in lower_all(a.out_dir).items():
        print(f"wrote {name}: {size} chars -> {path}")


if __name__ == "__main__":
    main()
