"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth for the L1 kernels (pytest compares
kernel output against these) and the semantic contract for the native rust
implementations in ``rust/src/core/criterion.rs`` (cross-checked by the
rust integration test ``runtime_matches_native``).

All functions operate on padded, fixed-shape tensors — padding rows/columns
are all-zero and must contribute exactly zero to every output (0·log 0 = 0).
"""

import jax.numpy as jnp

# Guard for log(0)/div-by-0; mirrors core::criterion::EPS on the rust side.
# We clamp denominators rather than add eps, so exact zeros stay exact.
_EPS = 1e-12


def _entropy(counts, axis=-1):
    """Shannon entropy (bits) of unnormalized count vectors along ``axis``.

    Empty distributions (all-zero counts, i.e. padding) yield entropy 0.
    """
    total = jnp.sum(counts, axis=axis, keepdims=True)
    p = counts / jnp.maximum(total, _EPS)
    logp = jnp.log2(jnp.maximum(p, _EPS))
    return -jnp.sum(jnp.where(counts > 0, p * logp, 0.0), axis=axis)


def infogain_ref(n):
    """Information gain per attribute.

    n: f32[A, V, C] — counters n_ijk for attribute a, value v, class c
       (one leaf's local-statistics table, padded with zeros).

    Returns (gain: f32[A], split_entropy: f32[A]):
      gain[a] = H(class) - sum_v (N_v/N) H(class | X_a = v)
      split_entropy[a] = entropy of the value marginals (gain-ratio
        diagnostics; 0 for padding attributes).

    Padding attributes (all-zero [V,C] blocks) get gain 0.
    """
    n = n.astype(jnp.float32)
    class_counts = jnp.sum(n, axis=1)          # [A, C]
    value_counts = jnp.sum(n, axis=2)          # [A, V]
    total = jnp.sum(class_counts, axis=1)      # [A]

    h_before = _entropy(class_counts, axis=1)  # [A]
    h_per_value = _entropy(n, axis=2)          # [A, V]
    w = value_counts / jnp.maximum(total[:, None], _EPS)
    h_after = jnp.sum(w * h_per_value, axis=1)  # [A]

    gain = jnp.where(total > 0, h_before - h_after, 0.0)
    split_h = _entropy(value_counts, axis=1)
    return gain, split_h


def sdr_ref(stats):
    """Standard-deviation reduction per attribute and candidate threshold.

    stats: f32[A, B, 3] — per attribute a and histogram bin b, the
      (count, sum, sum-of-squares) of the regression target over instances
      whose attribute value fell in bin b. Candidate threshold t_b splits
      bins [0..b] (left) vs (b..B) (right).

    Returns sdr: f32[A, B]:
        sdr[a,b] = sd(all) - (nL/N)·sd(left) - (nR/N)·sd(right)
    Thresholds with an empty side get SDR 0 (invalid), as does padding.
    """
    stats = stats.astype(jnp.float32)
    cum = jnp.cumsum(stats, axis=1)            # [A, B, 3] left stats
    tot = cum[:, -1:, :]                       # [A, 1, 3]
    left = cum
    right = tot - cum

    def sd(s):
        n, sm, sq = s[..., 0], s[..., 1], s[..., 2]
        mean = sm / jnp.maximum(n, _EPS)
        var = sq / jnp.maximum(n, _EPS) - mean * mean
        return jnp.sqrt(jnp.maximum(var, 0.0))

    n_tot = tot[..., 0]                        # [A, 1]
    n_l, n_r = left[..., 0], right[..., 0]     # [A, B]
    sdr = sd(tot) - (n_l / jnp.maximum(n_tot, _EPS)) * sd(left) \
                  - (n_r / jnp.maximum(n_tot, _EPS)) * sd(right)
    valid = (n_l > 0) & (n_r > 0)
    return jnp.where(valid, sdr, 0.0)


def cluster_assign_ref(points, centers, weights):
    """Nearest-micro-cluster assignment for CluStream.

    points:  f32[N, D] batch of incoming instances (zero-padded rows ok)
    centers: f32[K, D] micro-cluster centroids
    weights: f32[K]    micro-cluster weights; weight 0 marks an empty slot
                       (padding) which must never win the argmin.

    Returns (idx: i32[N], dist2: f32[N]): nearest live centroid index and
    its squared distance. Uses |x|^2 - 2 x·c + |c|^2 so the x·c term is a
    matmul (MXU path on real TPU).
    """
    points = points.astype(jnp.float32)
    centers = centers.astype(jnp.float32)
    x2 = jnp.sum(points * points, axis=1, keepdims=True)        # [N,1]
    c2 = jnp.sum(centers * centers, axis=1)[None, :]            # [1,K]
    d2 = x2 - 2.0 * (points @ centers.T) + c2                   # [N,K]
    d2 = jnp.maximum(d2, 0.0)
    big = jnp.float32(3.4e38)
    d2 = jnp.where(weights[None, :] > 0, d2, big)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return idx, jnp.min(d2, axis=1)
