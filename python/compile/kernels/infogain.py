"""L1 Pallas kernel: information gain over a local-statistics counter table.

The VHT local-statistics processor stores counters n_ijk as a dense block
``n[A, V, C]`` (attribute × value-bin × class). On a ``compute`` content
event it must produce the split-criterion value G_l(X_a) for every attribute
it tracks. That reduction is the numeric hot-spot of the whole SAMOA
pipeline and is what we express as a Pallas kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the attribute axis is the
grid; each grid step processes a ``[BA, V, C]`` tile streamed HBM→VMEM by
the BlockSpec — the on-chip analogue of SAMOA sharding attributes across
local-statistics processors. V and C are compile-time constants (histogram
bins / class count after padding), so every reduction below is over VMEM-
resident lanes. interpret=True everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernel is lowered through the interpreter to
plain HLO (same numerics, same blocking structure).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12

# Attribute-axis tile. [64, 16, 8] f32 = 32 KiB per tile — far inside a
# TPU core's ~16 MiB VMEM even with double buffering; chosen to keep the
# lane dimension (C=8 padded) dense and the sublane dim (V=16) aligned.
BLOCK_A = 64


def _entropy_sum(counts, axis):
    """-sum p log2 p with empty distributions contributing exactly 0."""
    total = jnp.sum(counts, axis=axis, keepdims=True)
    p = counts / jnp.maximum(total, _EPS)
    logp = jnp.log2(jnp.maximum(p, _EPS))
    return -jnp.sum(jnp.where(counts > 0, p * logp, 0.0), axis=axis)


def _infogain_kernel(n_ref, gain_ref, split_ref):
    """One grid step: [BA, V, C] counter tile → [BA] gain + split entropy."""
    n = n_ref[...].astype(jnp.float32)
    class_counts = jnp.sum(n, axis=1)            # [BA, C]
    value_counts = jnp.sum(n, axis=2)            # [BA, V]
    total = jnp.sum(class_counts, axis=1)        # [BA]

    h_before = _entropy_sum(class_counts, axis=1)
    h_per_value = _entropy_sum(n, axis=2)        # [BA, V]
    w = value_counts / jnp.maximum(total[:, None], _EPS)
    h_after = jnp.sum(w * h_per_value, axis=1)

    gain_ref[...] = jnp.where(total > 0, h_before - h_after, 0.0)
    split_ref[...] = _entropy_sum(value_counts, axis=1)


@functools.partial(jax.jit, static_argnames=("block_a",))
def infogain(n, block_a=BLOCK_A):
    """Per-attribute information gain. n: f32[A, V, C], A % block_a == 0.

    Returns (gain: f32[A], split_entropy: f32[A]).
    """
    a, v, c = n.shape
    assert a % block_a == 0, f"A={a} not a multiple of block {block_a}"
    grid = (a // block_a,)
    return pl.pallas_call(
        _infogain_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_a, v, c), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((block_a,), lambda i: (i,)),
            pl.BlockSpec((block_a,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a,), jnp.float32),
            jax.ShapeDtypeStruct((a,), jnp.float32),
        ],
        interpret=True,
    )(n)
