"""L1 Pallas kernel: standard-deviation reduction for AMRules expansion.

Each AMRules learner accumulates, per rule, per attribute and histogram bin,
the (count, sum, sum-of-squares) of the regression target. When a rule has
seen N_m new instances it evaluates every candidate feature "attribute a,
threshold after bin b" by the SDR measure (Ikonomovska et al.):

    sdr(a, b) = sd(all) - nL/N * sd(left) - nR/N * sd(right)

The kernel computes the full [A, B] SDR surface in one pass; the rust
learner then extracts best / second-best and applies the Hoeffding bound.

Grid is over attribute tiles, mirroring infogain.py; the cumulative sum
along the bin axis is VMEM-resident. interpret=True (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12

# [32, 64, 3] f32 tiles = 24 KiB; bins B is the sublane axis.
BLOCK_A = 32


def _sd(n, sm, sq):
    mean = sm / jnp.maximum(n, _EPS)
    var = sq / jnp.maximum(n, _EPS) - mean * mean
    return jnp.sqrt(jnp.maximum(var, 0.0))


def _sdr_kernel(stats_ref, sdr_ref):
    """One grid step: [BA, B, 3] bin stats → [BA, B] SDR surface."""
    s = stats_ref[...].astype(jnp.float32)
    cum = jnp.cumsum(s, axis=1)                 # left stats  [BA, B, 3]
    tot = cum[:, -1:, :]                        # [BA, 1, 3]
    right = tot - cum

    n_l, n_r = cum[..., 0], right[..., 0]
    n_tot = tot[..., 0]
    sd_tot = _sd(tot[..., 0], tot[..., 1], tot[..., 2])
    sd_l = _sd(cum[..., 0], cum[..., 1], cum[..., 2])
    sd_r = _sd(right[..., 0], right[..., 1], right[..., 2])

    sdr = sd_tot - (n_l / jnp.maximum(n_tot, _EPS)) * sd_l \
                 - (n_r / jnp.maximum(n_tot, _EPS)) * sd_r
    valid = (n_l > 0) & (n_r > 0)
    sdr_ref[...] = jnp.where(valid, sdr, 0.0)


@functools.partial(jax.jit, static_argnames=("block_a",))
def sdr(stats, block_a=BLOCK_A):
    """SDR surface. stats: f32[A, B, 3], A % block_a == 0 → f32[A, B]."""
    a, b, three = stats.shape
    assert three == 3
    assert a % block_a == 0, f"A={a} not a multiple of block {block_a}"
    grid = (a // block_a,)
    return pl.pallas_call(
        _sdr_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_a, b, 3), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_a, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        interpret=True,
    )(stats)
