"""L1 Pallas kernel: nearest micro-cluster assignment for CluStream.

The CluStream processor keeps K micro-clusters and must, for every incoming
instance, find the closest centroid (then absorb-or-spawn). Batched over N
instances this is a [N, D] × [D, K] distance computation — the one kernel in
this system with a matmul at its core, expressed so the x·cᵀ term hits the
MXU on a real TPU (bfloat16-friendly tile shapes, f32 accumulation).

Dead micro-cluster slots (weight 0, used for padding K up to the compile-
time shape) are masked to +inf before the argmin. interpret=True (CPU PJRT).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One tile: N=128 points × K=128 clusters × D≤128 dims → all operands
# comfortably in VMEM; the matmul is a single 128×128×128 MXU pass.
BLOCK_N = 128


def _assign_kernel(x_ref, c_ref, w_ref, idx_ref, d2_ref):
    x = x_ref[...].astype(jnp.float32)          # [BN, D]
    c = c_ref[...].astype(jnp.float32)          # [K, D]
    w = w_ref[...].astype(jnp.float32)          # [K]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [BN, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]        # [1, K]
    # MXU: [BN, D] @ [D, K]
    d2 = x2 - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) + c2
    d2 = jnp.maximum(d2, 0.0)
    big = jnp.float32(3.4e38)
    d2 = jnp.where(w[None, :] > 0, d2, big)
    idx_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2_ref[...] = jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("block_n",))
def cluster_assign(points, centers, weights, block_n=BLOCK_N):
    """points f32[N,D], centers f32[K,D], weights f32[K] → (i32[N], f32[N])."""
    n, d = points.shape
    k, d2 = centers.shape
    assert d == d2 and weights.shape == (k,)
    assert n % block_n == 0, f"N={n} not a multiple of block {block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(points, centers, weights)
