"""L2: the JAX compute graphs SAMOA's processors call at runtime.

Each public function here is one AOT artifact (see aot.py). They are thin
compositions over the L1 Pallas kernels plus the pre/post arithmetic that
belongs on-device (Hoeffding bound, top-2 selection) so that the rust side
receives decision-ready scalars and never re-enters Python.

Shapes are compile-time constants — the rust local-statistics processors
pad/chunk their tables to these (runtime::gain / runtime::sdr):

  infogain : n[A=64, V=16, C=8]            → gain[64], split_h[64]
  sdr      : stats[A=32, B=64, 3]          → sdr[32, 64]
  cluster  : x[N=128, D=64], c[K=128, D=64], w[K=128] → idx[128], d2[128]
  top2     : folded into infogain/sdr artifacts (best/second-best + ids)
"""

import jax.numpy as jnp

from .kernels.cluster import cluster_assign
from .kernels.infogain import infogain
from .kernels.sdr import sdr

# Compile-time shapes — keep in sync with rust/src/runtime/shapes.rs.
IG_A, IG_V, IG_C = 64, 16, 8
SDR_A, SDR_B = 32, 64
CL_N, CL_K, CL_D = 128, 128, 64


def _top2(values):
    """(best_idx, best, second_best) over a 1-D vector, on-device."""
    best_idx = jnp.argmax(values)
    best = values[best_idx]
    masked = values.at[best_idx].set(-jnp.inf)
    second = jnp.max(masked)
    return best_idx.astype(jnp.int32), best, second


def infogain_top2(n):
    """VHT `compute` event: counter table → per-attribute gains + top-2.

    n: f32[IG_A, IG_V, IG_C]. Returns a 4-tuple
    (gain[IG_A], best_idx, best_gain, second_gain) — the local-statistics
    processor forwards (best, second) as its local-result content event and
    keeps the full gain vector for diagnostics/ablation.
    """
    gain, _split_h = infogain(n)
    best_idx, best, second = _top2(gain)
    return gain, best_idx, best, second


def sdr_best(stats):
    """AMRules expansion: bin stats → SDR surface + flattened top-2.

    stats: f32[SDR_A, SDR_B, 3]. Returns
    (sdr[SDR_A, SDR_B], best_flat_idx, best, second) with flat index
    best_flat_idx = a * SDR_B + b.
    """
    surface = sdr(stats)
    flat = surface.reshape(-1)
    best_idx, best, second = _top2(flat)
    return surface, best_idx, best, second


def cluster_step(points, centers, weights):
    """CluStream batch assignment: see kernels/cluster.py."""
    idx, d2 = cluster_assign(points, centers, weights)
    return idx, d2


def example_args():
    """Example (ShapeDtypeStruct-able) args for each artifact, for aot.py."""
    import jax

    f32 = jnp.float32
    return {
        "infogain": (jax.ShapeDtypeStruct((IG_A, IG_V, IG_C), f32),),
        "sdr": (jax.ShapeDtypeStruct((SDR_A, SDR_B, 3), f32),),
        "cluster": (
            jax.ShapeDtypeStruct((CL_N, CL_D), f32),
            jax.ShapeDtypeStruct((CL_K, CL_D), f32),
            jax.ShapeDtypeStruct((CL_K,), f32),
        ),
    }


ENTRYPOINTS = {
    "infogain": infogain_top2,
    "sdr": sdr_best,
    "cluster": cluster_step,
}
